//! Command-line interface (hand-rolled arg parsing; clap is unavailable
//! offline). `pysiglib help` for usage.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::Config;
use crate::coordinator::{serve, Batcher, BatcherConfig, Router};
use crate::kernel::{KernelOptions, SolverKind};
use crate::sig::{SigMethod, SigOptions};
use crate::transforms::Transform;
use crate::util::rng::Rng;

const HELP: &str = "pysiglib — fast signature-based computations (paper reproduction)

USAGE:
  pysiglib <command> [flags]

COMMANDS:
  sig        compute a batch of truncated signatures on synthetic paths
             --batch N --len L --dim D --depth N --transform none|time|leadlag
             --method horner|direct --serial
             --repeat R compile the plan once, execute it R times (the
                        engine's compile-once/execute-many session API)
             --ragged   variable-length paths in [L/2, L] (typed PathBatch
                        API, no padding)
  logsig     compute log-signatures       (same flags as sig)
  kernel     compute a batch of signature kernels
             --batch N --len L --dim D --dyadic λ --dyadic2 λ2
             --solver row|blocked --transform ... --repeat R
             --scheme order1|order2   Goursat discretisation order
             --target-eps E  pick the cheapest (scheme, λ) meeting relative
                        error E instead of the fixed --dyadic grid
             --ragged   variable-length (x, y) pairs in [L/2, L]
             --lifted linear|rbf [--sigma S]  static-kernel lift (drives the
                        PDE with κ's second difference instead of ⟨dx, dy⟩;
                        ignores --transform/--solver/--repeat)
  mmd        signature-kernel MMD² between two synthetic corpora
             --batch N --len L --dim D --dyadic λ --transform ...
             --unbiased        U-statistic instead of the biased V-statistic
             --rank R          low-rank approximation (0 = exact Gram path)
             --landmarks R     Nyström with R landmarks (implies --rank R)
             --features nystrom|randsig  --depth N (randsig truncation)
             --seed S          landmark / sketch seed
             --scheme/--target-eps as for kernel (exact path only)
  grad       exact signature-kernel gradients for a batch of pairs
             --batch N --len L --dim D --dyadic λ --scheme ... --target-eps E
  corpus     corpus registry lifecycle (register → query → append → stream)
             corpus register --addr A --batch N --len L --dim D
             corpus append   --addr A --id I --batch K --len L --dim D
             corpus mmd      --addr A --id I --batch Q --len L --dim D
                             --rank R (0 = exact) --repeat N
             corpus mmd without --addr runs the full lifecycle in-process
             (register, cold + warm queries, append --append K, re-query)
             and prints the warm-over-cold speedup; --lanes W pins the
             lane width (0 scalar, 4, 8; default: PYSIGLIB_LANES or the
             shape-class default) and --tile T the Gram tile edge, with
             lane/tile occupancy printed after the run
             corpus watch  --batch N --len L --dim D --window W --decay G
                           --threshold T --calm C --drift K
             live drift-monitor demo: streams calm then drifted paths
             through a sliding window scored by weighted MMD² against the
             reference corpus, printing per-path samples and alarms, then
             extends a reference path in place and prints the Goursat
             border-strip occupancy (O(L_new·L) cells, not O(L²)); with
             --addr the windows are scored over the wire instead
             corpus snapshot --addr A  ask the server to snapshot every
             registered corpus (paths + warm derived state) to its
             configured --snapshot-dir; prints the number written
  serve      run the serving coordinator
             --bind ADDR --max-batch N --max-wait-us U --pjrt --config FILE
             --queue-cap N --global-cap N  bounded admission: excess load is
                        shed with a typed Overloaded + retry hint
             --deadline-us U  per-request deadline (0 = none); expired work
                        is answered DeadlineExceeded, never computed
             --snapshot-dir D  restore corpora from D on start, snapshot to
                        D on drain (and on `corpus snapshot`)
  client     demo client: fires requests at a running server
             --addr ADDR --requests N --len L --dim D
  artifacts  list + compile + smoke-run the AOT artifacts  --dir PATH
  selfcheck  cross-check native vs baselines (and PJRT if artifacts exist)
  help       this text
";

/// Parse `--key value` and `--flag` style arguments.
fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            let takes_value = i + 1 < args.len() && !args[i + 1].starts_with("--");
            if takes_value {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag_usize(f: &HashMap<String, String>, key: &str, default: usize) -> usize {
    f.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn flag_transform(f: &HashMap<String, String>) -> Transform {
    f.get("transform")
        .and_then(|v| Transform::parse(v))
        .unwrap_or(Transform::None)
}

/// Apply the shared accuracy flags (`--scheme order1|order2`,
/// `--target-eps E`) to a kernel-options builder. Values are validated
/// here only for parseability; ε semantics (finite, > 0) are enforced at
/// plan compile.
fn apply_accuracy_flags(
    mut opts: KernelOptions,
    flags: &HashMap<String, String>,
) -> Result<KernelOptions, String> {
    match flags.get("scheme").map(String::as_str) {
        None => {}
        Some("order1") => opts = opts.scheme(crate::kernel::Scheme::Order1),
        Some("order2") => opts = opts.scheme(crate::kernel::Scheme::Order2),
        Some(other) => return Err(format!("unknown scheme '{other}' (expected order1|order2)")),
    }
    if let Some(v) = flags.get("target-eps") {
        let eps: f64 = v
            .parse()
            .map_err(|_| format!("--target-eps '{v}' is not a number"))?;
        opts = opts.target_eps(eps);
    }
    Ok(opts)
}

/// CLI entry point; returns the process exit code.
pub fn cli_main(args: &[String]) -> i32 {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &[] } else { &args[1..] };
    let (_pos, flags) = parse_flags(rest);
    match cmd {
        "sig" | "logsig" => cmd_sig(cmd == "logsig", &flags),
        "kernel" => cmd_kernel(&flags),
        "mmd" => cmd_mmd(&flags),
        "grad" => cmd_grad(&flags),
        "corpus" => cmd_corpus(&_pos, &flags),
        "serve" => cmd_serve(&flags),
        "client" => cmd_client(&flags),
        "artifacts" => cmd_artifacts(&flags),
        "selfcheck" => cmd_selfcheck(),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            2
        }
    }
}

fn cmd_sig(log: bool, flags: &HashMap<String, String>) -> i32 {
    let batch = flag_usize(flags, "batch", 32);
    let len = flag_usize(flags, "len", 128);
    let dim = flag_usize(flags, "dim", 4);
    let depth = flag_usize(flags, "depth", 4);
    let tr = flag_transform(flags);
    let method = match flags.get("method").map(String::as_str) {
        Some("direct") => SigMethod::Direct,
        _ => SigMethod::Horner,
    };
    let mut rng = Rng::new(42);
    let opts = {
        let mut o = SigOptions::new(depth).transform(tr).method(method);
        if flags.contains_key("serial") {
            o = o.serial();
        }
        o
    };
    if flags.contains_key("ragged") {
        return cmd_sig_ragged(log, batch, len, dim, &opts, &mut rng);
    }
    // The engine's session API: compile the shape class's plan once, then
    // execute it --repeat times — the steady state allocates nothing.
    let repeat = flag_usize(flags, "repeat", 1).max(1);
    let paths = rng.brownian_batch(batch, len, dim, 0.3);
    let session = crate::engine::Session::new();
    let spec = if log {
        crate::engine::OpSpec::LogSig(opts)
    } else {
        crate::engine::OpSpec::Sig(opts)
    };
    let plan = match session.forward_plan(spec, crate::engine::ShapeClass::uniform(dim, len)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("plan compilation failed: {e}");
            return 2;
        }
    };
    let pb = match crate::path::PathBatch::uniform(&paths, batch, len, dim) {
        Ok(pb) => pb,
        Err(e) => {
            eprintln!("invalid batch: {e}");
            return 2;
        }
    };
    let t = std::time::Instant::now();
    let (mut width, mut checksum) = (0usize, 0.0);
    for _ in 0..repeat {
        match plan.execute(&pb) {
            Ok(rec) => {
                width = if batch == 0 { 0 } else { rec.values().len() / batch };
                checksum = rec.values().iter().sum::<f64>();
            }
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "{} batch={batch} len={len} dim={dim} depth={depth} transform={tr:?} width={width} repeat={repeat}",
        if log { "logsig" } else { "sig" }
    );
    println!(
        "time={dt:.6}s  throughput={:.1} paths/s  arena_allocs={}  checksum={checksum:.6e}",
        (batch * repeat) as f64 / dt,
        plan.allocations(),
    );
    0
}

/// Ragged variant of `sig`/`logsig`: variable-length paths through the typed
/// `PathBatch` API — no padding, one flat buffer plus an offset table.
fn cmd_sig_ragged(
    log: bool,
    batch: usize,
    len: usize,
    dim: usize,
    opts: &SigOptions,
    rng: &mut Rng,
) -> i32 {
    let lo = (len / 2).max(1);
    let lengths: Vec<usize> = (0..batch).map(|_| rng.range(lo, len.max(lo))).collect();
    let mut data = Vec::new();
    for &l in &lengths {
        data.extend(rng.brownian_path(l, dim, 0.3));
    }
    let pb = match crate::path::PathBatch::ragged(&data, &lengths, dim) {
        Ok(pb) => pb,
        Err(e) => {
            eprintln!("invalid ragged batch: {e}");
            return 2;
        }
    };
    let t = std::time::Instant::now();
    let result = if log {
        crate::sig::try_batch_log_signature(&pb, opts)
    } else {
        crate::sig::try_batch_signature(&pb, opts)
    };
    let out = match result {
        Ok(out) => out,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let dt = t.elapsed().as_secs_f64();
    let total = pb.total_points();
    let padded = batch * len;
    println!(
        "{} ragged batch={batch} len∈[{lo},{len}] dim={dim} depth={} width={}",
        if log { "logsig" } else { "sig" },
        opts.depth,
        if batch == 0 { 0 } else { out.len() / batch },
    );
    println!(
        "time={dt:.6}s  throughput={:.1} paths/s  points={total} ({:.0}% of padded)  checksum={:.6e}",
        batch as f64 / dt,
        100.0 * total as f64 / padded.max(1) as f64,
        out.iter().sum::<f64>()
    );
    0
}

/// The `--lifted` route of the kernel command: static-kernel lifts
/// (`StaticKernel::Linear` recovers the plain kernel; `Rbf` lifts the path
/// values into an RBF feature space before the PDE solve).
fn cmd_kernel_lifted(
    kind: &str,
    batch: usize,
    len: usize,
    dim: usize,
    lam1: u32,
    lam2: u32,
    flags: &HashMap<String, String>,
) -> i32 {
    let sigma = flags
        .get("sigma")
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(1.0);
    let kappa = match kind {
        "linear" => crate::kernel::StaticKernel::Linear,
        "rbf" => crate::kernel::StaticKernel::Rbf { sigma },
        other => {
            eprintln!("unknown static kernel '{other}' (expected linear|rbf)");
            return 2;
        }
    };
    if len < 2 {
        eprintln!("--lifted needs paths of at least 2 points");
        return 2;
    }
    let mut rng = Rng::new(43);
    let x = rng.brownian_batch(batch, len, dim, 0.3);
    let y = rng.brownian_batch(batch, len, dim, 0.3);
    let mut ks = vec![0.0; batch];
    let t = std::time::Instant::now();
    crate::util::pool::parallel_for_mut(&mut ks, 1, |i, slot| {
        slot[0] = crate::kernel::sig_kernel_lifted(
            &x[i * len * dim..(i + 1) * len * dim],
            &y[i * len * dim..(i + 1) * len * dim],
            len,
            len,
            dim,
            kappa,
            lam1,
            lam2,
        );
    });
    let dt = t.elapsed().as_secs_f64();
    println!("kernel batch={batch} len={len} dim={dim} dyadic=({lam1},{lam2}) lifted={kappa:?}");
    println!(
        "time={dt:.6}s  throughput={:.1} kernels/s  mean_k={:.6}",
        batch as f64 / dt,
        ks.iter().sum::<f64>() / batch.max(1) as f64
    );
    0
}

fn cmd_kernel(flags: &HashMap<String, String>) -> i32 {
    let batch = flag_usize(flags, "batch", 32);
    let len = flag_usize(flags, "len", 128);
    let dim = flag_usize(flags, "dim", 4);
    let lam1 = flag_usize(flags, "dyadic", 0) as u32;
    let lam2 = flag_usize(flags, "dyadic2", lam1 as usize) as u32;
    if let Some(kind) = flags.get("lifted") {
        return cmd_kernel_lifted(kind, batch, len, dim, lam1, lam2, flags);
    }
    let solver = match flags.get("solver").map(String::as_str) {
        Some("blocked") => SolverKind::Blocked,
        _ => SolverKind::Row,
    };
    let tr = flag_transform(flags);
    let mut rng = Rng::new(43);
    let opts = match apply_accuracy_flags(
        KernelOptions::default()
            .dyadic(lam1, lam2)
            .solver(solver)
            .transform(tr),
        flags,
    ) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let (ks, dt, desc) = if flags.contains_key("ragged") {
        // Variable-length (x, y) pairs through the typed API — each pair is
        // solved on its own (lx−1) × (ly−1) grid, no padding anywhere.
        let lo = (len / 2).max(2);
        let hi = len.max(lo);
        let make = |rng: &mut Rng| -> (Vec<usize>, Vec<f64>) {
            let lengths: Vec<usize> = (0..batch).map(|_| rng.range(lo, hi)).collect();
            let mut data = Vec::new();
            for &l in &lengths {
                data.extend(rng.brownian_path(l, dim, 0.3));
            }
            (lengths, data)
        };
        let (xl, xdata) = make(&mut rng);
        let (yl, ydata) = make(&mut rng);
        let t = std::time::Instant::now();
        let xb = crate::path::PathBatch::ragged(&xdata, &xl, dim);
        let yb = crate::path::PathBatch::ragged(&ydata, &yl, dim);
        let ks = match (xb, yb) {
            (Ok(xb), Ok(yb)) => match crate::kernel::try_batch_kernel(&xb, &yb, &opts) {
                Ok(ks) => ks,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 1;
                }
            },
            _ => {
                eprintln!("invalid ragged batch");
                return 2;
            }
        };
        (ks, t.elapsed().as_secs_f64(), format!("len∈[{lo},{hi}]"))
    } else {
        // Session-compiled plan, executed --repeat times on the same shape.
        let repeat = flag_usize(flags, "repeat", 1).max(1);
        let x = rng.brownian_batch(batch, len, dim, 0.3);
        let y = rng.brownian_batch(batch, len, dim, 0.3);
        let session = crate::engine::Session::new();
        let plan = match session.forward_plan(
            crate::engine::OpSpec::SigKernel(opts),
            crate::engine::ShapeClass::uniform(dim, len),
        ) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("plan compilation failed: {e}");
                return 2;
            }
        };
        let (xb, yb) = match (
            crate::path::PathBatch::uniform(&x, batch, len, dim),
            crate::path::PathBatch::uniform(&y, batch, len, dim),
        ) {
            (Ok(xb), Ok(yb)) => (xb, yb),
            _ => {
                eprintln!("invalid batch");
                return 2;
            }
        };
        let t = std::time::Instant::now();
        let mut ks = Vec::new();
        for r in 0..repeat {
            match plan.execute_pair(&xb, &yb) {
                // Only the final record detaches its buffer; intermediate
                // ones return theirs to the arena so the steady state stays
                // allocation-free.
                Ok(rec) if r + 1 == repeat => ks = rec.into_values(),
                Ok(_) => {}
                Err(e) => {
                    eprintln!("error: {e}");
                    return 1;
                }
            }
        }
        (ks, t.elapsed().as_secs_f64(), format!("len={len} repeat={repeat}"))
    };
    println!(
        "kernel batch={batch} {desc} dim={dim} dyadic=({lam1},{lam2}) solver={solver:?} transform={tr:?}"
    );
    println!(
        "time={dt:.6}s  throughput={:.1} kernels/s  mean_k={:.6}",
        batch as f64 / dt,
        ks.iter().sum::<f64>() / batch.max(1) as f64
    );
    0
}

/// MMD² between two synthetic corpora — exact (quadratic in batch) or
/// rank-budgeted through the low-rank feature maps (`--rank`/`--landmarks`).
fn cmd_mmd(flags: &HashMap<String, String>) -> i32 {
    let batch = flag_usize(flags, "batch", 32);
    let len = flag_usize(flags, "len", 64);
    let dim = flag_usize(flags, "dim", 3);
    let lam = flag_usize(flags, "dyadic", 0) as u32;
    let tr = flag_transform(flags);
    let unbiased = flags.contains_key("unbiased");
    let seed = flag_usize(flags, "seed", 7) as u64;
    // --landmarks N is Nyström shorthand; --rank + --features picks a family.
    let landmarks = flag_usize(flags, "landmarks", 0);
    if landmarks > 0 && flags.get("features").map(String::as_str) == Some("randsig") {
        eprintln!("--landmarks selects Nyström; it cannot be combined with --features randsig");
        return 2;
    }
    let rank = if landmarks > 0 {
        landmarks
    } else {
        flag_usize(flags, "rank", 0)
    };
    let opts = match apply_accuracy_flags(KernelOptions::default().dyadic(lam, lam).transform(tr), flags)
    {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // The low-rank feature maps fix their grid up front; adaptive ε
    // resolution is an exact-path feature.
    if rank > 0 && flags.contains_key("target-eps") {
        eprintln!("--target-eps applies to the exact path only (drop --rank/--landmarks)");
        return 2;
    }
    let mut rng = Rng::new(48);
    // Two corpora of slightly different scale, so the MMD is nonzero.
    let x = rng.brownian_batch(batch, len, dim, 0.30);
    let y = rng.brownian_batch(batch, len, dim, 0.35);
    let (xb, yb) = match (
        crate::path::PathBatch::uniform(&x, batch, len, dim),
        crate::path::PathBatch::uniform(&y, batch, len, dim),
    ) {
        (Ok(xb), Ok(yb)) => (xb, yb),
        _ => {
            eprintln!("invalid batch");
            return 2;
        }
    };
    let estimator = if unbiased { "unbiased" } else { "biased" };
    let t = std::time::Instant::now();
    let (value, desc) = if rank == 0 {
        let r = if unbiased {
            crate::kernel::try_mmd2_unbiased(&xb, &yb, &opts)
        } else {
            crate::kernel::try_mmd2(&xb, &yb, &opts)
        };
        match r {
            Ok(v) => (v, "exact".to_string()),
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    } else {
        let spec = match flags.get("features").map(String::as_str) {
            Some("randsig") => crate::kernel::LowRankSpec::random_sig(
                rank,
                flag_usize(flags, "depth", 4),
                seed,
            ),
            Some("nystrom") | None => crate::kernel::LowRankSpec::nystrom(rank, seed),
            Some(other) => {
                eprintln!("unknown feature family '{other}' (expected nystrom|randsig)");
                return 2;
            }
        };
        // Landmarks from y — the same convention as the engine's
        // Mmd2LowRank plans (exact x-gradients for training loops).
        let map = match crate::kernel::FeatureMap::try_build(&spec, &opts, &yb) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("feature map construction failed: {e}");
                return 1;
            }
        };
        let r = if unbiased {
            crate::kernel::try_mmd2_lowrank_unbiased(&map, &xb, &yb)
        } else {
            crate::kernel::try_mmd2_lowrank(&map, &xb, &yb)
        };
        use crate::kernel::LowRankFeatures;
        match r {
            Ok(v) => (v, format!("lowrank rank={}", map.rank())),
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    };
    let dt = t.elapsed().as_secs_f64();
    println!(
        "mmd batch={batch} len={len} dim={dim} dyadic={lam} transform={tr:?} estimator={estimator} ({desc})"
    );
    println!("time={dt:.6}s  mmd2={value:.6e}");
    0
}

fn cmd_grad(flags: &HashMap<String, String>) -> i32 {
    let batch = flag_usize(flags, "batch", 16);
    let len = flag_usize(flags, "len", 64);
    let dim = flag_usize(flags, "dim", 4);
    let lam = flag_usize(flags, "dyadic", 0) as u32;
    let mut rng = Rng::new(44);
    let x = rng.brownian_batch(batch, len, dim, 0.3);
    let y = rng.brownian_batch(batch, len, dim, 0.3);
    let gk = vec![1.0; batch];
    let opts = match apply_accuracy_flags(KernelOptions::default().dyadic(lam, lam), flags) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let t = std::time::Instant::now();
    let (gx, gy) = crate::kernel::batch_kernel_vjp(&x, &y, &gk, batch, len, len, dim, &opts);
    let dt = t.elapsed().as_secs_f64();
    println!("grad batch={batch} len={len} dim={dim} dyadic={lam}");
    println!(
        "time={dt:.6}s  |gx|={:.6e} |gy|={:.6e}",
        crate::util::linalg::norm2(&gx),
        crate::util::linalg::norm2(&gy)
    );
    0
}

/// `corpus register|append|mmd`: the registry lifecycle, either against a
/// running server (`--addr`) or — for `mmd` without `--addr` — as an
/// in-process demo that registers, queries cold and warm, appends, and
/// re-queries, printing per-stage latencies and the warm speedup.
fn cmd_corpus(pos: &[String], flags: &HashMap<String, String>) -> i32 {
    let sub = pos.first().map(String::as_str).unwrap_or("");
    if sub == "watch" {
        return cmd_corpus_watch(flags);
    }
    let batch = flag_usize(flags, "batch", 64);
    let len = flag_usize(flags, "len", 32);
    let dim = flag_usize(flags, "dim", 3);
    let rank = flag_usize(flags, "rank", 0) as u32;
    let mut rng = Rng::new(flag_usize(flags, "seed", 47) as u64);
    let make_paths = |rng: &mut Rng, n: usize| -> Vec<Vec<f64>> {
        (0..n).map(|_| rng.brownian_path(len, dim, 0.3)).collect()
    };
    if let Some(addr) = flags.get("addr") {
        let mut client = match crate::coordinator::Client::connect(addr.as_str()) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("connect {addr}: {e}");
                return 1;
            }
        };
        let id = flag_usize(flags, "id", 0) as u32;
        let paths = make_paths(&mut rng, batch);
        let refs: Vec<&[f64]> = paths.iter().map(|p| p.as_slice()).collect();
        let outcome: Result<String, String> = match sub {
            "register" => client
                .register_corpus(&refs, dim)
                .map_err(|e| e.to_string())
                .and_then(|r| r)
                .map(|id| format!("registered corpus id={id} paths={batch}")),
            "append" => client
                .append_corpus(id, &refs, dim)
                .map_err(|e| e.to_string())
                .and_then(|r| r)
                .map(|total| format!("appended {batch} paths to id={id}; total={total}")),
            "snapshot" => client
                .snapshot_corpus()
                .map_err(|e| e.to_string())
                .and_then(|r| r)
                .map(|n| format!("snapshotted {n} corpora to the server's snapshot dir")),
            "mmd" => {
                let repeat = flag_usize(flags, "repeat", 1).max(1);
                let t = std::time::Instant::now();
                let mut value = Ok(0.0);
                for _ in 0..repeat {
                    value = client
                        .mmd2_corpus(id, &refs, dim, rank)
                        .map_err(|e| e.to_string())
                        .and_then(|r| r);
                    if value.is_err() {
                        break;
                    }
                }
                let dt = t.elapsed().as_secs_f64();
                value.map(|v| {
                    format!(
                        "mmd2={v:.6e} id={id} queries={batch} rank={rank} repeat={repeat} \
                         time={dt:.6}s ({:.6}s/query)",
                        dt / repeat as f64
                    )
                })
            }
            other => {
                eprintln!(
                    "unknown corpus subcommand '{other}' \
                     (expected register|append|mmd|snapshot|watch)"
                );
                return 2;
            }
        };
        match outcome {
            Ok(msg) => {
                println!("{msg}");
                0
            }
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        }
    } else {
        if sub != "mmd" {
            eprintln!("corpus {sub}: --addr is required (register/append need a running server)");
            return 2;
        }
        // In-process lifecycle demo against a local registry.
        let queries = flag_usize(flags, "queries", 8.min(batch.max(1)));
        let appended = flag_usize(flags, "append", (batch / 4).max(1));
        let mut tiles = match flags.get("tile") {
            Some(t) => crate::corpus::TileScheduler::with_tile(
                t.parse().ok().filter(|&v: &usize| v >= 1).unwrap_or(16),
            ),
            None => crate::corpus::TileScheduler::from_env(),
        };
        if let Some(w) = flags.get("lanes").and_then(|v| v.parse::<usize>().ok()) {
            tiles = tiles.with_lanes(w);
        }
        let registry = crate::corpus::CorpusRegistry::with_tiles(tiles);
        let lane_stats_before = crate::kernel::lanes::stats();
        let corpus = rng.brownian_batch(batch, len, dim, 0.3);
        let qdata = rng.brownian_batch(queries, len, dim, 0.35);
        let extra = rng.brownian_batch(appended, len, dim, 0.3);
        let opts = KernelOptions::default();
        let lowrank =
            (rank > 0).then(|| crate::kernel::LowRankSpec::nystrom(rank as usize, 47));
        let run = || -> Result<(), crate::path::SigError> {
            let cb = crate::path::PathBatch::uniform(&corpus, batch, len, dim)?;
            let qb = crate::path::PathBatch::uniform(&qdata, queries, len, dim)?;
            let eb = crate::path::PathBatch::uniform(&extra, appended, len, dim)?;
            let id = registry.register(&cb)?;
            let t = std::time::Instant::now();
            let cold = registry.mmd2_query(id, &qb, &opts, lowrank.as_ref())?;
            let t_cold = t.elapsed().as_secs_f64();
            let t = std::time::Instant::now();
            let warm = registry.mmd2_query(id, &qb, &opts, lowrank.as_ref())?;
            let t_warm = t.elapsed().as_secs_f64();
            assert_eq!(cold, warm, "warm re-query must be bit-identical");
            let t = std::time::Instant::now();
            let total = registry.append(id, &eb)?;
            let t_append = t.elapsed().as_secs_f64();
            let t = std::time::Instant::now();
            let post = registry.mmd2_query(id, &qb, &opts, lowrank.as_ref())?;
            let t_post = t.elapsed().as_secs_f64();
            println!(
                "corpus demo: n={batch} (+{appended} appended, total {total}) queries={queries} \
                 len={len} dim={dim} rank={rank}"
            );
            println!("  cold query   {t_cold:>10.6}s  mmd2={cold:.6e}");
            println!("  warm query   {t_warm:>10.6}s  (bit-identical)");
            println!("  append       {t_append:>10.6}s  (incremental tiles)");
            println!("  post query   {t_post:>10.6}s  mmd2={post:.6e}");
            println!(
                "  warm speedup {:.1}x  stats: {:?}",
                t_cold / t_warm.max(1e-12),
                registry.stats()
            );
            let ls = crate::kernel::lanes::stats();
            println!(
                "  lane occupancy: tiles={} lane_groups={} scalar_pairs={} (width {})",
                ls.tiles_executed - lane_stats_before.tiles_executed,
                ls.lane_groups - lane_stats_before.lane_groups,
                ls.scalar_pairs - lane_stats_before.scalar_pairs,
                tiles
                    .lane_width()
                    .map(|w| w.to_string())
                    .unwrap_or_else(|| "auto".to_string()),
            );
            Ok(())
        };
        match run() {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        }
    }
}

/// `corpus watch`: the live drift-monitor demo. In-process it registers a
/// reference corpus, streams calm then drifted paths through a
/// [`DriftMonitor`](crate::corpus::DriftMonitor) (sliding window scored by
/// exponentially-weighted MMD² against the reference), prints every sample,
/// then extends one reference path in place and reports the Goursat
/// border-strip occupancy — the steady-state extension solves `O(L_new·L)`
/// cells, not the `O(L²)` grid. With `--addr` the same windows are scored
/// over the wire through the `Mmd2Window` op instead.
fn cmd_corpus_watch(flags: &HashMap<String, String>) -> i32 {
    let batch = flag_usize(flags, "batch", 16);
    let len = flag_usize(flags, "len", 32);
    let dim = flag_usize(flags, "dim", 2);
    let capacity = flag_usize(flags, "window", 4).max(1);
    let calm = flag_usize(flags, "calm", 6);
    let drifted = flag_usize(flags, "drift", 6);
    let decay = flags
        .get("decay")
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.9);
    let threshold = flags
        .get("threshold")
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(1e-3);
    let mut rng = Rng::new(flag_usize(flags, "seed", 49) as u64);
    // The drift phase is a deterministic trend the Brownian reference never
    // shows, so the alarm fires reliably in a demo run.
    let trend_path = |len: usize, dim: usize| -> Vec<f64> {
        (0..len * dim).map(|j| (j / dim) as f64 * 0.9).collect()
    };

    if let Some(addr) = flags.get("addr") {
        // Wire mode: register the reference, then score each live window
        // through the weighted window op.
        let mut client = match crate::coordinator::Client::connect(addr.as_str()) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("connect {addr}: {e}");
                return 1;
            }
        };
        let reference: Vec<Vec<f64>> = (0..batch)
            .map(|_| rng.brownian_path(len, dim, 0.3))
            .collect();
        let refs: Vec<&[f64]> = reference.iter().map(|p| p.as_slice()).collect();
        let id = match client.register_corpus(&refs, dim) {
            Ok(Ok(id)) => id,
            Ok(Err(e)) => {
                eprintln!("server error: {e}");
                return 1;
            }
            Err(e) => {
                eprintln!("io error: {e}");
                return 1;
            }
        };
        let decay_bp = ((decay * 10_000.0).round()).clamp(1.0, 10_000.0) as u32;
        println!(
            "corpus watch (wire): id={id} n={batch} len={len} dim={dim} window={capacity} \
             decay_bp={decay_bp} threshold={threshold:.1e}"
        );
        let mut window: std::collections::VecDeque<Vec<f64>> = std::collections::VecDeque::new();
        for t in 0..calm + drifted {
            let path = if t < calm {
                rng.brownian_path(len, dim, 0.3)
            } else {
                trend_path(len, dim)
            };
            window.push_back(path);
            while window.len() > capacity {
                window.pop_front();
            }
            let wrefs: Vec<&[f64]> = window.iter().map(|p| p.as_slice()).collect();
            match client.mmd2_window(id, &wrefs, dim, decay_bp) {
                Ok(Ok(v)) => println!(
                    "  t={t:>3} phase={} window={} mmd2={v:.6e}{}",
                    if t < calm { "calm " } else { "drift" },
                    wrefs.len(),
                    if v > threshold { "  ALARM" } else { "" }
                ),
                Ok(Err(e)) => {
                    eprintln!("server error: {e}");
                    return 1;
                }
                Err(e) => {
                    eprintln!("io error: {e}");
                    return 1;
                }
            }
        }
        return 0;
    }

    // In-process mode: the full DriftMonitor, then a border-strip demo.
    let registry = Arc::new(crate::corpus::CorpusRegistry::new());
    let reference = rng.brownian_batch(batch, len, dim, 0.3);
    let run = |rng: &mut Rng| -> Result<(), crate::path::SigError> {
        let rb = crate::path::PathBatch::uniform(&reference, batch, len, dim)?;
        let id = registry.register(&rb)?;
        let opts = KernelOptions::default();
        let mut monitor = crate::corpus::DriftMonitor::try_new(
            registry.clone(),
            id,
            opts,
            capacity,
            decay,
            threshold,
            3,
        )?;
        println!(
            "corpus watch: reference n={batch} len={len} dim={dim} window={capacity} \
             decay={decay} threshold={threshold:.1e}"
        );
        let mut alarms = 0usize;
        for t in 0..calm + drifted {
            let path = if t < calm {
                rng.brownian_path(len, dim, 0.3)
            } else {
                trend_path(len, dim)
            };
            let sample = monitor.observe(&path, len)?;
            if sample.alarm {
                alarms += 1;
            }
            println!(
                "  t={t:>3} phase={} window={} mmd2={:.6e}{}",
                if t < calm { "calm " } else { "drift" },
                sample.window_len,
                sample.mmd2,
                if sample.alarm { "  ALARM" } else { "" }
            );
        }
        println!("  alarms={alarms} (drift phase had {drifted} paths)");
        // Streaming extension: the first extend pays a one-time full
        // retaining solve per touched pair; the second advances only the
        // O(L_new·L) border strips.
        let add = 4usize;
        let warmup = rng.brownian_path(add, dim, 0.3);
        let c0 = crate::kernel::border_cells_solved();
        let t = std::time::Instant::now();
        registry.extend_path(id, 0, &warmup)?;
        let t_warm = t.elapsed().as_secs_f64();
        let c1 = crate::kernel::border_cells_solved();
        let strip = rng.brownian_path(add, dim, 0.3);
        let t = std::time::Instant::now();
        let new_len = registry.extend_path(id, 0, &strip)?;
        let t_strip = t.elapsed().as_secs_f64();
        let c2 = crate::kernel::border_cells_solved();
        println!(
            "  extend_path(+{add} pts, path 0 → {new_len}): warm-up {t_warm:.6}s \
             ({} cells incl. retaining solves), steady-state {t_strip:.6}s ({} strip cells)",
            c1 - c0,
            c2 - c1,
        );
        println!("  stats: {:?}", registry.stats());
        Ok(())
    };
    match run(&mut rng) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn build_config(flags: &HashMap<String, String>) -> Result<Config, String> {
    let mut cfg = Config::default();
    if let Some(path) = flags.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        cfg.apply_file_text(&text).map_err(|e| e.to_string())?;
    }
    cfg.apply_env().map_err(|e| e.to_string())?;
    if let Some(v) = flags.get("bind") {
        cfg.bind = v.clone();
    }
    if let Some(v) = flags.get("max-batch") {
        cfg.set("max_batch", v).map_err(|e| e.to_string())?;
    }
    if let Some(v) = flags.get("max-wait-us") {
        cfg.set("max_wait_us", v).map_err(|e| e.to_string())?;
    }
    if let Some(v) = flags.get("queue-cap") {
        cfg.set("queue_cap", v).map_err(|e| e.to_string())?;
    }
    if let Some(v) = flags.get("global-cap") {
        cfg.set("global_cap", v).map_err(|e| e.to_string())?;
    }
    if let Some(v) = flags.get("deadline-us") {
        cfg.set("deadline_us", v).map_err(|e| e.to_string())?;
    }
    if let Some(v) = flags.get("snapshot-dir") {
        cfg.snapshot_dir = v.clone();
    }
    if flags.contains_key("pjrt") {
        cfg.use_pjrt = true;
    }
    if let Some(v) = flags.get("artifacts") {
        cfg.artifacts_dir = v.clone();
    }
    Ok(cfg)
}

fn cmd_serve(flags: &HashMap<String, String>) -> i32 {
    let cfg = match build_config(flags) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let mut router = if cfg.use_pjrt {
        match crate::runtime::RuntimeHandle::spawn(&cfg.artifacts_dir) {
            Ok(rt) => {
                println!("PJRT runtime on {} ({} artifacts)", rt.platform(), rt.manifest().len());
                Router::with_runtime(rt)
            }
            Err(e) => {
                eprintln!("warning: PJRT unavailable ({e:#}); native backend only");
                Router::native_only()
            }
        }
    } else {
        Router::native_only()
    };
    if !cfg.snapshot_dir.is_empty() {
        router = router.with_snapshot_dir(std::path::PathBuf::from(&cfg.snapshot_dir));
        match router.restore_corpora() {
            Ok(0) => {}
            Ok(n) => println!("restored {n} corpora from {}", cfg.snapshot_dir),
            Err(e) => eprintln!("warning: corpus snapshot not restored ({e}); starting cold"),
        }
    }
    let batcher = Arc::new(Batcher::start(
        Arc::new(router),
        BatcherConfig {
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            queue_cap: cfg.queue_cap,
            global_cap: cfg.global_cap,
            deadline: cfg.deadline,
        },
    ));
    let handle = match serve(cfg.bind.as_str(), batcher.clone()) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("bind {}: {e}", cfg.bind);
            return 1;
        }
    };
    println!(
        "serving on {} (max_batch={}, max_wait={:?})",
        handle.addr, cfg.max_batch, cfg.max_wait
    );
    // Periodic metrics until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        println!("{}", batcher.metrics.summary());
    }
}

fn cmd_client(flags: &HashMap<String, String>) -> i32 {
    let addr = flags
        .get("addr")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7462".to_string());
    let n = flag_usize(flags, "requests", 64);
    let len = flag_usize(flags, "len", 64);
    let dim = flag_usize(flags, "dim", 3);
    let mut client = match crate::coordinator::Client::connect(addr.as_str()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect {addr}: {e}");
            return 1;
        }
    };
    let mut rng = Rng::new(45);
    let t = std::time::Instant::now();
    let mut ok = 0usize;
    for i in 0..n {
        let x = rng.brownian_path(len, dim, 0.3);
        let y = rng.brownian_path(len, dim, 0.3);
        let r = if i % 2 == 0 {
            client.signature(&x, len, dim, 4).map(|r| r.map(|_| ()))
        } else {
            client.sig_kernel(&x, &y, len, dim).map(|r| r.map(|_| ()))
        };
        match r {
            Ok(Ok(())) => ok += 1,
            Ok(Err(e)) => eprintln!("server error: {e}"),
            Err(e) => {
                eprintln!("io error: {e}");
                return 1;
            }
        }
    }
    let dt = t.elapsed().as_secs_f64();
    println!("{ok}/{n} ok in {dt:.3}s ({:.1} req/s)", n as f64 / dt);
    0
}

fn cmd_artifacts(flags: &HashMap<String, String>) -> i32 {
    let dir = flags
        .get("dir")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let rt = match crate::runtime::Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("{e:#}");
            return 1;
        }
    };
    println!("platform: {}", rt.platform());
    let mut failures = 0;
    for info in rt.manifest().to_vec() {
        // Smoke-run with deterministic inputs.
        let inputs: Vec<Vec<f32>> = info
            .input_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.iter().product();
                (0..n).map(|j| ((j + i) % 17) as f32 * 0.01).collect()
            })
            .collect();
        match rt.execute_f32(&info.name, &inputs) {
            Ok(outs) => {
                let sizes: Vec<usize> = outs.iter().map(|o| o.len()).collect();
                println!("  {} inputs={:?} outputs={sizes:?} OK", info.name, info.input_shapes);
            }
            Err(e) => {
                println!("  {} FAILED: {e:#}", info.name);
                failures += 1;
            }
        }
    }
    failures
}

fn cmd_selfcheck() -> i32 {
    let mut rng = Rng::new(46);
    let mut bad = 0;
    // Signature: horner vs direct vs naive.
    let p = rng.brownian_path(32, 3, 0.4);
    let h = crate::sig::signature(&p, 32, 3, 4, Transform::None, SigMethod::Horner);
    let d = crate::sig::signature(&p, 32, 3, 4, Transform::None, SigMethod::Direct);
    let n = crate::baselines::naive_signature(&p, 32, 3, 4);
    let e1 = crate::util::linalg::max_abs_diff(&h, &d);
    let e2 = crate::util::linalg::max_abs_diff(&h, &n);
    println!("signature horner-vs-direct: {e1:.2e}, horner-vs-naive: {e2:.2e}");
    if e1 > 1e-9 || e2 > 1e-9 {
        bad += 1;
    }
    // Kernel: row vs blocked vs full-grid baseline.
    let x = rng.brownian_path(40, 3, 0.3);
    let y = rng.brownian_path(36, 3, 0.3);
    let (m, nn, delta) = crate::kernel::delta_matrix(&x, &y, 40, 36, 3, Transform::None);
    let kr = crate::kernel::solve_pde(&delta, m, nn, 1, 1);
    let kb = crate::kernel::solve_pde_blocked(&delta, m, nn, 1, 1);
    let kf = crate::baselines::full_grid_kernel(&delta, m, nn, 1, 1).unwrap();
    println!("kernel row={kr:.9} blocked={kb:.9} fullgrid={kf:.9}");
    if (kr - kb).abs() > 1e-9 || (kr - kf).abs() > 1e-9 {
        bad += 1;
    }
    // PJRT parity if artifacts are present.
    if let Ok(rt) = crate::runtime::Runtime::new("artifacts") {
        if rt.info("sigkernel_b8_l16_d3").is_some() {
            let b = 8;
            let (l, dim) = (16, 3);
            let xs = rng.brownian_batch(b, l, dim, 0.3);
            let ys = rng.brownian_batch(b, l, dim, 0.3);
            let native = crate::kernel::batch_kernel(
                &xs,
                &ys,
                b,
                l,
                l,
                dim,
                &KernelOptions::default(),
            );
            let xf: Vec<f32> = xs.iter().map(|&v| v as f32).collect();
            let yf: Vec<f32> = ys.iter().map(|&v| v as f32).collect();
            match rt.execute_f32("sigkernel_b8_l16_d3", &[xf, yf]) {
                Ok(outs) => {
                    let got: Vec<f64> = outs[0].iter().map(|&v| v as f64).collect();
                    let rel = crate::util::linalg::rel_err(&got, &native);
                    println!("pjrt-vs-native sigkernel rel err: {rel:.2e}");
                    if rel > 1e-4 {
                        bad += 1;
                    }
                }
                Err(e) => {
                    println!("pjrt execution failed: {e:#}");
                    bad += 1;
                }
            }
        }
    } else {
        println!("(artifacts not built; skipping PJRT parity — run `make artifacts`)");
    }
    if bad == 0 {
        println!("selfcheck OK");
        0
    } else {
        println!("selfcheck FAILED ({bad} problems)");
        1
    }
}
