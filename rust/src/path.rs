//! Typed core API: paths, path batches and the unified options layer.
//!
//! Every public computation in this crate is available in two forms:
//!
//! 1. **Typed, fallible** — take a [`Path`] / [`PathBatch`] (shape-checked at
//!    construction) and return `Result<_, SigError>`. These are the primary
//!    implementations; nothing on this route panics on malformed input, which
//!    is what the serving coordinator requires (a bad frame must become an
//!    `Err` response, not kill a worker).
//! 2. **Flat slices + scalars** — the original `&[f64]` + `len/dim/batch`
//!    entry points, kept as thin wrappers that construct the typed views and
//!    unwrap (panicking on shape errors, as they always did).
//!
//! [`PathBatch`] supports **ragged** batches via an offset table: paths of
//! different lengths live back-to-back in one flat buffer, so variable-length
//! corpora no longer need padding. Signature rows stay uniform (the signature
//! length depends only on `dim` and `depth`), Gram matrices pair every length
//! with every other, and gradients come back in the same ragged layout.

use crate::kernel::scheme::{Scheme, TargetEps};
use crate::kernel::SolverKind;
use crate::sig::SigMethod;
use crate::transforms::Transform;

/// Errors from the typed API. Shape problems are caught at `Path`/`PathBatch`
/// construction or entry-point validation; `Protocol`/`Backend` carry the
/// serving-layer failure modes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SigError {
    /// A path must have at least one point.
    EmptyPath,
    /// Path dimension must be at least 1.
    ZeroDim,
    /// Truncation depth must be at least 1.
    ZeroDepth,
    /// Flat buffer length disagrees with the declared shape.
    DataLen { expected: usize, got: usize },
    /// Two batches that must pair up have different sizes.
    BatchMismatch { left: usize, right: usize },
    /// Two paths/batches that must share a dimension do not.
    DimMismatch { left: usize, right: usize },
    /// A cotangent / weight buffer has the wrong length.
    CotangentLen { expected: usize, got: usize },
    /// An estimator needs more paths than the batch provides.
    InsufficientBatch { need: usize, got: usize },
    /// Unknown transform code (wire encoding).
    BadTransform(u8),
    /// A size computation overflowed or exceeded the hard cap — hostile or
    /// absurd shape parameters (e.g. an enormous depth from the wire).
    TooLarge(&'static str),
    /// An argument is invalid for the requested operation, or an input does
    /// not belong to the shape class a [`Plan`](crate::engine::Plan) was
    /// compiled for.
    Invalid(&'static str),
    /// Numerical failure (overflow / not positive definite).
    NonFinite(&'static str),
    /// Malformed wire frame or header.
    Protocol(String),
    /// Compute-backend failure (e.g. PJRT execution).
    Backend(String),
    /// A corpus snapshot failed validation (bad magic/version, truncated
    /// file, or a mandatory section whose content hash does not match).
    /// Corrupt *derived-state* sections never raise this — they are dropped
    /// and rebuilt lazily (see [`corpus::persist`](crate::corpus::persist)).
    SnapshotCorrupt(String),
}

impl std::fmt::Display for SigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SigError::EmptyPath => write!(f, "path must have at least one point"),
            SigError::ZeroDim => write!(f, "path dimension must be at least 1"),
            SigError::ZeroDepth => write!(f, "truncation depth must be at least 1"),
            SigError::DataLen { expected, got } => {
                write!(f, "path buffer has {got} values, expected {expected}")
            }
            SigError::BatchMismatch { left, right } => {
                write!(f, "batch sizes differ: {left} vs {right}")
            }
            SigError::DimMismatch { left, right } => {
                write!(f, "path dimensions differ: {left} vs {right}")
            }
            SigError::CotangentLen { expected, got } => {
                write!(f, "cotangent buffer has {got} values, expected {expected}")
            }
            SigError::InsufficientBatch { need, got } => {
                write!(f, "estimator needs at least {need} paths, got {got}")
            }
            SigError::BadTransform(code) => write!(f, "unknown transform code {code}"),
            SigError::TooLarge(what) => write!(f, "size overflow in {what}"),
            SigError::Invalid(what) => write!(f, "invalid argument: {what}"),
            SigError::NonFinite(what) => write!(f, "numerical failure: {what}"),
            SigError::Protocol(msg) => write!(f, "protocol error: {msg}"),
            SigError::Backend(msg) => write!(f, "backend error: {msg}"),
            SigError::SnapshotCorrupt(msg) => write!(f, "snapshot corrupt: {msg}"),
        }
    }
}

impl std::error::Error for SigError {}

/// A borrowed, shape-checked view of one path: row-major `[len, dim]` with
/// `len >= 1` and `dim >= 1` guaranteed by construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Path<'a> {
    data: &'a [f64],
    len: usize,
    dim: usize,
}

impl<'a> Path<'a> {
    /// Validate `data` as a `[len, dim]` path.
    pub fn new(data: &'a [f64], len: usize, dim: usize) -> Result<Path<'a>, SigError> {
        if dim == 0 {
            return Err(SigError::ZeroDim);
        }
        if len == 0 {
            return Err(SigError::EmptyPath);
        }
        let expected = len
            .checked_mul(dim)
            .ok_or(SigError::TooLarge("path size"))?;
        if data.len() != expected {
            return Err(SigError::DataLen {
                expected,
                got: data.len(),
            });
        }
        Ok(Path { data, len, dim })
    }

    /// Infer the length from the buffer: `data.len()` must be a non-zero
    /// multiple of `dim`.
    pub fn from_flat(data: &'a [f64], dim: usize) -> Result<Path<'a>, SigError> {
        if dim == 0 {
            return Err(SigError::ZeroDim);
        }
        if data.is_empty() {
            return Err(SigError::EmptyPath);
        }
        if data.len() % dim != 0 {
            return Err(SigError::DataLen {
                expected: (data.len() / dim + 1) * dim,
                got: data.len(),
            });
        }
        Path::new(data, data.len() / dim, dim)
    }

    /// Flat `[len, dim]` row-major values.
    pub fn data(&self) -> &'a [f64] {
        self.data
    }

    /// Number of points (at least 1).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Always false: a `Path` has at least one point by construction.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Dimension of each point (at least 1).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Point `i` as a `dim`-slice.
    pub fn point(&self, i: usize) -> &'a [f64] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }
}

/// A borrowed batch of paths sharing one dimension, uniform or **ragged**.
///
/// Paths live back-to-back in one flat buffer; an offset table (in points)
/// records where each starts. Uniform batches are the special case where all
/// lengths agree, and constructors record that so downstream code can keep
/// its uniform fast paths.
#[derive(Clone, Debug, PartialEq)]
pub struct PathBatch<'a> {
    data: &'a [f64],
    dim: usize,
    /// Point offsets: path `i` spans points `offsets[i]..offsets[i+1]`.
    /// Always `batch + 1` entries, starting at 0, non-decreasing.
    offsets: Vec<usize>,
    /// `Some(len)` when every path has exactly `len` points.
    uniform: Option<usize>,
}

impl<'a> PathBatch<'a> {
    /// A uniform batch: `data` is row-major `[batch, len, dim]`.
    pub fn uniform(
        data: &'a [f64],
        batch: usize,
        len: usize,
        dim: usize,
    ) -> Result<PathBatch<'a>, SigError> {
        if dim == 0 {
            return Err(SigError::ZeroDim);
        }
        if len == 0 {
            return Err(SigError::EmptyPath);
        }
        let expected = batch
            .checked_mul(len)
            .and_then(|v| v.checked_mul(dim))
            .ok_or(SigError::TooLarge("uniform batch size"))?;
        if data.len() != expected {
            return Err(SigError::DataLen {
                expected,
                got: data.len(),
            });
        }
        Ok(PathBatch {
            data,
            dim,
            offsets: (0..=batch).map(|i| i * len).collect(),
            uniform: Some(len),
        })
    }

    /// A ragged batch: path `i` has `lengths[i]` points, all back-to-back in
    /// `data`. Every length must be at least 1.
    pub fn ragged(
        data: &'a [f64],
        lengths: &[usize],
        dim: usize,
    ) -> Result<PathBatch<'a>, SigError> {
        if dim == 0 {
            return Err(SigError::ZeroDim);
        }
        let mut offsets = Vec::with_capacity(lengths.len() + 1);
        let mut total = 0usize;
        offsets.push(0);
        for &l in lengths {
            if l == 0 {
                return Err(SigError::EmptyPath);
            }
            total = total
                .checked_add(l)
                .ok_or(SigError::TooLarge("ragged batch size"))?;
            offsets.push(total);
        }
        let expected = total
            .checked_mul(dim)
            .ok_or(SigError::TooLarge("ragged batch size"))?;
        if data.len() != expected {
            return Err(SigError::DataLen {
                expected,
                got: data.len(),
            });
        }
        let uniform = match lengths.first() {
            Some(&l0) if lengths.iter().all(|&l| l == l0) => Some(l0),
            _ => None,
        };
        Ok(PathBatch {
            data,
            dim,
            offsets,
            uniform,
        })
    }

    /// Number of paths.
    pub fn batch(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.batch() == 0
    }

    /// Shared dimension of every path.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `Some(len)` when every path has the same number of points (always the
    /// case for [`PathBatch::uniform`]; `None` for genuinely ragged batches
    /// and for empty ragged batches).
    pub fn uniform_len(&self) -> Option<usize> {
        self.uniform
    }

    /// Total number of points across the batch.
    pub fn total_points(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Number of points of path `i`.
    pub fn len_of(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// Path `i` as a typed view.
    pub fn path(&self, i: usize) -> Path<'a> {
        Path {
            data: self.values_of(i),
            len: self.len_of(i),
            dim: self.dim,
        }
    }

    /// Flat values of path `i` (`[len_of(i), dim]` row-major).
    pub fn values_of(&self, i: usize) -> &'a [f64] {
        &self.data[self.offsets[i] * self.dim..self.offsets[i + 1] * self.dim]
    }

    /// Point offsets (length `batch + 1`, starting at 0).
    pub fn offsets(&self) -> &[usize] {
        &self.offsets
    }

    /// The whole flat buffer.
    pub fn data(&self) -> &'a [f64] {
        self.data
    }

    /// Iterate over the paths.
    pub fn iter(&self) -> impl Iterator<Item = Path<'a>> + '_ {
        (0..self.batch()).map(move |i| self.path(i))
    }

    /// Element offsets (in `f64`s, not points) — chunk `i` of a flat ragged
    /// per-point output spans `element_offsets[i]..element_offsets[i+1]`.
    pub fn element_offsets(&self) -> Vec<usize> {
        self.offsets.iter().map(|&o| o * self.dim).collect()
    }
}

/// Execution policy shared by every batched entry point in both subsystems
/// (signatures and kernels): which path transform to fuse on-the-fly, and
/// whether to parallelise over the batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ExecOptions {
    /// Applied on-the-fly; the transformed path is never materialised.
    pub transform: Transform,
    /// Parallelise over the batch dimension.
    pub parallel: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            transform: Transform::None,
            parallel: true,
        }
    }
}

impl ExecOptions {
    pub fn transform(mut self, t: Transform) -> Self {
        self.transform = t;
        self
    }
    pub fn serial(mut self) -> Self {
        self.parallel = false;
        self
    }
}

/// Options for (batched) signature computation. The transform/parallel policy
/// lives in [`ExecOptions`], shared with [`KernelOptions`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SigOptions {
    pub depth: usize,
    pub method: SigMethod,
    pub exec: ExecOptions,
}

impl SigOptions {
    pub fn new(depth: usize) -> Self {
        SigOptions {
            depth,
            method: SigMethod::Horner,
            exec: ExecOptions::default(),
        }
    }
    pub fn transform(mut self, t: Transform) -> Self {
        self.exec.transform = t;
        self
    }
    pub fn method(mut self, m: SigMethod) -> Self {
        self.method = m;
        self
    }
    pub fn serial(mut self) -> Self {
        self.exec.parallel = false;
        self
    }
    /// Error unless the options are usable (depth at least 1).
    pub fn validate(&self) -> Result<(), SigError> {
        if self.depth == 0 {
            return Err(SigError::ZeroDepth);
        }
        Ok(())
    }
}

/// Options for signature-kernel computations. The transform/parallel policy
/// lives in [`ExecOptions`], shared with [`SigOptions`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct KernelOptions {
    /// Dyadic refinement order for the first path (λ1).
    pub dyadic_x: u32,
    /// Dyadic refinement order for the second path (λ2). The paper allows
    /// λ1 ≠ λ2 — useful when x and y have very different lengths.
    pub dyadic_y: u32,
    pub solver: SolverKind,
    /// Goursat discretisation order ([`Scheme::Order1`] is the paper's
    /// update; `Order2` Richardson-extrapolates against the (λ1−1, λ2−1)
    /// grid for the same accuracy on coarser grids).
    pub scheme: Scheme,
    /// Optional error target replacing fixed λ: when set, solves probe a
    /// subsample and pick the cheapest (scheme, λ) meeting ε (see
    /// [`resolve_target_eps`](crate::kernel::scheme::resolve_target_eps)).
    pub target_eps: TargetEps,
    pub exec: ExecOptions,
}

impl Default for KernelOptions {
    fn default() -> Self {
        KernelOptions {
            dyadic_x: 0,
            dyadic_y: 0,
            solver: SolverKind::Row,
            scheme: Scheme::Order1,
            target_eps: TargetEps::UNSET,
            exec: ExecOptions::default(),
        }
    }
}

impl KernelOptions {
    pub fn dyadic(mut self, l1: u32, l2: u32) -> Self {
        self.dyadic_x = l1;
        self.dyadic_y = l2;
        self
    }
    pub fn solver(mut self, s: SolverKind) -> Self {
        self.solver = s;
        self
    }
    /// Select the Goursat discretisation scheme.
    pub fn scheme(mut self, s: Scheme) -> Self {
        self.scheme = s;
        self
    }
    /// Set an error target ε; validated at plan compile (finite, > 0).
    pub fn target_eps(mut self, eps: f64) -> Self {
        self.target_eps = TargetEps::new(eps);
        self
    }
    pub fn transform(mut self, t: Transform) -> Self {
        self.exec.transform = t;
        self
    }
    pub fn serial(mut self) -> Self {
        self.exec.parallel = false;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_rejects_bad_shapes() {
        assert_eq!(Path::new(&[1.0, 2.0], 1, 0), Err(SigError::ZeroDim));
        assert_eq!(Path::new(&[], 0, 2), Err(SigError::EmptyPath));
        assert_eq!(
            Path::new(&[1.0, 2.0, 3.0], 2, 2),
            Err(SigError::DataLen {
                expected: 4,
                got: 3
            })
        );
        let data = [1.0, 2.0, 3.0, 4.0];
        let p = Path::new(&data, 2, 2).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.dim(), 2);
        assert_eq!(p.point(1), &[3.0, 4.0]);
    }

    #[test]
    fn from_flat_infers_length() {
        let data = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = Path::from_flat(&data, 3).unwrap();
        assert_eq!(p.len(), 2);
        assert!(Path::from_flat(&data, 4).is_err());
        assert!(Path::from_flat(&[], 2).is_err());
    }

    #[test]
    fn uniform_batch_offsets() {
        let data = [0.0; 12]; // 2 paths × 3 points × 2 dims
        let b = PathBatch::uniform(&data, 2, 3, 2).unwrap();
        assert_eq!(b.batch(), 2);
        assert_eq!(b.uniform_len(), Some(3));
        assert_eq!(b.offsets(), &[0, 3, 6]);
        assert_eq!(b.total_points(), 6);
        assert_eq!(b.path(1).len(), 3);
    }

    #[test]
    fn ragged_batch_offsets_and_views() {
        let data: Vec<f64> = (0..10).map(|v| v as f64).collect(); // 5 points in R^2
        let b = PathBatch::ragged(&data, &[2, 1, 2], 2).unwrap();
        assert_eq!(b.batch(), 3);
        assert_eq!(b.uniform_len(), None);
        assert_eq!(b.len_of(1), 1);
        assert_eq!(b.values_of(1), &[4.0, 5.0]);
        assert_eq!(b.path(2).point(1), &[8.0, 9.0]);
        assert_eq!(b.element_offsets(), vec![0, 4, 6, 10]);
    }

    #[test]
    fn ragged_batch_rejects_bad_shapes() {
        let data = [0.0; 4];
        assert_eq!(
            PathBatch::ragged(&data, &[2, 0], 2),
            Err(SigError::EmptyPath)
        );
        assert!(PathBatch::ragged(&data, &[3], 2).is_err());
        assert_eq!(PathBatch::ragged(&data, &[2], 0), Err(SigError::ZeroDim));
    }

    #[test]
    fn empty_batches_are_fine() {
        let b = PathBatch::ragged(&[], &[], 2).unwrap();
        assert_eq!(b.batch(), 0);
        assert_eq!(b.uniform_len(), None);
        assert_eq!(b.total_points(), 0);
        let u = PathBatch::uniform(&[], 0, 4, 2).unwrap();
        assert_eq!(u.batch(), 0);
        assert_eq!(u.uniform_len(), Some(4));
    }

    #[test]
    fn ragged_with_equal_lengths_reports_uniform() {
        let data = [0.0; 8];
        let b = PathBatch::ragged(&data, &[2, 2], 2).unwrap();
        assert_eq!(b.uniform_len(), Some(2));
    }

    #[test]
    fn options_share_the_exec_layer() {
        let s = SigOptions::new(3).transform(Transform::TimeAug).serial();
        assert_eq!(s.exec.transform, Transform::TimeAug);
        assert!(!s.exec.parallel);
        let k = KernelOptions::default().transform(Transform::LeadLag);
        assert_eq!(k.exec.transform, Transform::LeadLag);
        assert!(k.exec.parallel);
        assert!(SigOptions::new(0).validate().is_err());
    }
}
