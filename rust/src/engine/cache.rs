//! The LRU plan cache and the user-facing [`Session`].
//!
//! A [`Session`] is the compile-once/execute-many front door: ask it for a
//! plan and repeated requests for the same (op, shape class) return the same
//! warm [`Plan`] — workspaces already sized, layout tables already built.
//! The serving router holds one cache per process so repeated traffic
//! classes skip compilation entirely; its hit/miss/eviction counters are
//! surfaced in the server metrics snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::corpus::CorpusRegistry;
use crate::path::SigError;
use crate::runtime::RuntimeHandle;

use super::{OpSpec, Plan, PlanKey, ShapeClass};

/// Cache observability counters (monotonic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// A bounded LRU cache of compiled plans keyed by (op, shape class,
/// retention). Thread-safe; lookups move the entry to the back, inserts
/// evict from the front.
pub struct PlanCache {
    capacity: usize,
    entries: Mutex<Vec<(PlanKey, Arc<Plan>)>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            capacity: capacity.max(1),
            entries: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Warm lookup or compile-and-insert. Non-cacheable specs (KRR, which
    /// carries an `f64` hyperparameter) compile fresh and count as misses.
    pub fn get_or_compile(
        &self,
        spec: OpSpec,
        shape: ShapeClass,
        retain: bool,
        runtime: Option<Arc<RuntimeHandle>>,
    ) -> Result<Arc<Plan>, SigError> {
        let key = spec.cache_key(shape, retain);
        self.lookup_or_insert(key, || Plan::compile_custom(spec, shape, retain, runtime))
    }

    /// [`get_or_compile`](Self::get_or_compile) for corpus-query specs
    /// ([`OpSpec::GramCorpus`] / [`OpSpec::Mmd2Corpus`] /
    /// [`OpSpec::Mmd2Window`]): compiled via [`Plan::compile_corpus`] with
    /// the serving registry. The corpus id is part of the cache key; a
    /// cached plan stays valid across appends because it resolves the id
    /// against the registry on every execute. `Mmd2Window` carries an `f64`
    /// decay, so (like KRR) it has no key and compiles fresh.
    pub fn get_or_compile_corpus(
        &self,
        spec: OpSpec,
        shape: ShapeClass,
        registry: &Arc<CorpusRegistry>,
    ) -> Result<Arc<Plan>, SigError> {
        let key = spec.cache_key(shape, false);
        self.lookup_or_insert(key, || Plan::compile_corpus(spec, shape, registry.clone()))
    }

    /// The shared LRU body: warm lookup (moving the hit to the back),
    /// compile on miss, insert, evict from the front. `None` keys
    /// (non-cacheable specs) compile fresh and count as misses. The compile
    /// runs outside the lock; a racing duplicate insert is harmless (last
    /// one wins, the loser is just dropped on eviction).
    fn lookup_or_insert(
        &self,
        key: Option<PlanKey>,
        compile: impl FnOnce() -> Result<Plan, SigError>,
    ) -> Result<Arc<Plan>, SigError> {
        let Some(key) = key else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return compile().map(Arc::new);
        };
        {
            let mut entries = self.entries.lock().unwrap();
            if let Some(pos) = entries.iter().position(|(k, _)| *k == key) {
                let entry = entries.remove(pos);
                let plan = entry.1.clone();
                entries.push(entry);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(plan);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(compile()?);
        let mut entries = self.entries.lock().unwrap();
        entries.push((key, plan.clone()));
        while entries.len() > self.capacity {
            entries.remove(0);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok(plan)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A compile-once/execute-many session: a plan cache plus an optional PJRT
/// runtime for backend dispatch. Use it when the same shape classes recur
/// (training loops, serving); use the `try_*` convenience wrappers for
/// one-off calls.
pub struct Session {
    cache: PlanCache,
    runtime: Option<Arc<RuntimeHandle>>,
}

impl Default for Session {
    fn default() -> Self {
        Session::new()
    }
}

impl Session {
    /// Native-backend session with a default-sized plan cache.
    pub fn new() -> Session {
        Session::with_capacity(32)
    }

    pub fn with_capacity(capacity: usize) -> Session {
        Session {
            cache: PlanCache::new(capacity),
            runtime: None,
        }
    }

    /// Session that dispatches to PJRT artifacts when shapes match.
    pub fn with_runtime(runtime: Arc<RuntimeHandle>) -> Session {
        Session {
            cache: PlanCache::new(32),
            runtime: Some(runtime),
        }
    }

    /// A record-keeping plan (supports [`vjp`](super::ExecutionRecord::vjp)).
    pub fn plan(&self, spec: OpSpec, shape: ShapeClass) -> Result<Arc<Plan>, SigError> {
        self.cache
            .get_or_compile(spec, shape, true, self.runtime.clone())
    }

    /// A forward-only plan — the cheapest steady state for serving.
    pub fn forward_plan(&self, spec: OpSpec, shape: ShapeClass) -> Result<Arc<Plan>, SigError> {
        self.cache
            .get_or_compile(spec, shape, false, self.runtime.clone())
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::path::SigOptions;

    #[test]
    fn repeat_lookups_hit() {
        let s = Session::new();
        let spec = OpSpec::Sig(SigOptions::new(3));
        let shape = ShapeClass::uniform(2, 16);
        let p1 = s.plan(spec, shape).unwrap();
        let p2 = s.plan(spec, shape).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "second lookup must be the warm plan");
        let st = s.cache_stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        // A different shape class is a different plan.
        let p3 = s.plan(spec, ShapeClass::uniform(2, 17)).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3));
    }

    #[test]
    fn forward_and_retained_plans_are_distinct() {
        let s = Session::new();
        let spec = OpSpec::Sig(SigOptions::new(2));
        let shape = ShapeClass::uniform(2, 8);
        let a = s.plan(spec, shape).unwrap();
        let b = s.forward_plan(spec, shape).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn lru_evicts_oldest() {
        let c = PlanCache::new(2);
        let spec = OpSpec::Sig(SigOptions::new(2));
        for len in [4usize, 5, 6] {
            c.get_or_compile(spec, ShapeClass::uniform(2, len), false, None)
                .unwrap();
        }
        assert_eq!(c.len(), 2);
        let st = c.stats();
        assert_eq!(st.evictions, 1);
        // len=4 was evicted: looking it up again is a miss.
        c.get_or_compile(spec, ShapeClass::uniform(2, 4), false, None)
            .unwrap();
        assert_eq!(c.stats().misses, 4);
        // len=6 is still warm.
        c.get_or_compile(spec, ShapeClass::uniform(2, 6), false, None)
            .unwrap();
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn compile_errors_are_not_cached() {
        let c = PlanCache::new(4);
        let bad = OpSpec::Sig(SigOptions::new(0));
        assert!(c
            .get_or_compile(bad, ShapeClass::uniform(2, 8), false, None)
            .is_err());
        assert_eq!(c.len(), 0);
    }
}
