//! The workspace arena behind compiled [`Plan`](super::Plan)s: a shared pool
//! of reusable buffers with an allocation counter, so steady-state
//! `plan.execute(..)` performs **zero shape-dependent heap allocation** —
//! every buffer whose size depends on the batch shape (outputs, increment
//! scratch, Δ matrices, PDE rows and grids, offset tables) is checked out of
//! the pool and returned when the [`ExecutionRecord`](super::ExecutionRecord)
//! drops.
//!
//! The counter only moves when a checkout cannot be served from the free
//! list; the engine's unit tests assert it stays flat across repeated
//! executions of the same plan on same-shape inputs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Keep at most this many idle buffers per pool; beyond it, returned buffers
/// are simply dropped (bounds memory held by long-lived cached plans).
const MAX_FREE: usize = 256;

/// Also bound the *total capacity* a pool may hold idle (2^27 f64s = 1 GiB):
/// long-lived cached plans (the serving router keeps plans for the process
/// lifetime) must not pin a one-off worst-case workspace forever. Working
/// sets under the cap keep the zero-allocation steady state; a single
/// monster request beyond it trades steady-state reuse for bounded RSS.
const MAX_POOLED: usize = 1 << 27;

#[derive(Default)]
struct ArenaInner {
    f64s: Mutex<Vec<Vec<f64>>>,
    usizes: Mutex<Vec<Vec<usize>>>,
    allocations: AtomicU64,
}

/// Pool a returned buffer if both the count and total-capacity bounds allow
/// it; otherwise drop it.
fn give_bounded<T>(free: &mut Vec<Vec<T>>, buf: Vec<T>, max_free: usize, max_pooled: usize) {
    let held: usize = free.iter().map(|b| b.capacity()).sum();
    if free.len() < max_free && held + buf.capacity() <= max_pooled {
        free.push(buf);
    }
}

/// Cheaply clonable handle to a buffer pool shared by a plan and the records
/// it produces.
#[derive(Clone, Default)]
pub struct Arena {
    inner: Arc<ArenaInner>,
}

/// Best-fit checkout: the free buffer with the smallest sufficient capacity.
/// With identical request multisets across runs this is order-independent —
/// a warm pool always serves a repeat execution without allocating.
fn best_fit<T>(free: &mut Vec<Vec<T>>, len: usize) -> Option<Vec<T>> {
    let mut best: Option<usize> = None;
    for (i, buf) in free.iter().enumerate() {
        let cap = buf.capacity();
        if cap < len {
            continue;
        }
        match best {
            Some(b) if free[b].capacity() <= cap => {}
            _ => best = Some(i),
        }
    }
    best.map(|i| free.swap_remove(i))
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Number of fresh heap allocations the arena has performed. Flat across
    /// two executions of the same plan on same-shape inputs (the zero-alloc
    /// steady-state contract).
    pub fn allocations(&self) -> u64 {
        self.inner.allocations.load(Ordering::Relaxed)
    }

    /// Check out a zeroed `f64` buffer of exactly `len` elements.
    ///
    /// Reused buffers are deliberately re-zeroed even though most hot-path
    /// consumers fully overwrite them: several (signature rows on the len<2
    /// path, per-pair Δ regions around degenerate pairs) rely on zeroed
    /// storage, and a non-zeroing variant would make that invariant
    /// per-call-site instead of structural. Revisit only with a benchmark
    /// showing the memset on the largest (grid) buffers matters.
    pub(crate) fn take(&self, len: usize) -> Vec<f64> {
        if len == 0 {
            return Vec::new(); // never touches the pool, never counts
        }
        let reused = best_fit(&mut self.inner.f64s.lock().unwrap(), len);
        match reused {
            Some(mut buf) => {
                buf.clear();
                buf.resize(len, 0.0); // within capacity: no allocation
                buf
            }
            None => {
                self.inner.allocations.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        }
    }

    /// Check out a zeroed `usize` buffer of exactly `len` elements.
    pub(crate) fn take_usize(&self, len: usize) -> Vec<usize> {
        if len == 0 {
            return Vec::new();
        }
        let reused = best_fit(&mut self.inner.usizes.lock().unwrap(), len);
        match reused {
            Some(mut buf) => {
                buf.clear();
                buf.resize(len, 0);
                buf
            }
            None => {
                self.inner.allocations.fetch_add(1, Ordering::Relaxed);
                vec![0; len]
            }
        }
    }

    /// Return a buffer to the pool (no-op for never-allocated buffers;
    /// dropped instead of pooled past the count/byte bounds).
    pub(crate) fn give(&self, buf: Vec<f64>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut free = self.inner.f64s.lock().unwrap();
        give_bounded(&mut free, buf, MAX_FREE, MAX_POOLED);
    }

    pub(crate) fn give_usize(&self, buf: Vec<usize>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut free = self.inner.usizes.lock().unwrap();
        give_bounded(&mut free, buf, MAX_FREE, MAX_POOLED);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_does_not_allocate() {
        let a = Arena::new();
        let b1 = a.take(100);
        let b2 = a.take(10);
        assert_eq!(a.allocations(), 2);
        a.give(b1);
        a.give(b2);
        // Same request multiset, different order: served from the pool.
        let c1 = a.take(10);
        let c2 = a.take(100);
        assert_eq!(a.allocations(), 2);
        assert_eq!(c1.len(), 10);
        assert!(c2.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let a = Arena::new();
        let small = a.take(8);
        let big = a.take(1000);
        a.give(big);
        a.give(small);
        let got = a.take(4);
        assert!(got.capacity() < 1000, "best fit must pick the small buffer");
        a.give(got);
        assert_eq!(a.allocations(), 2);
    }

    #[test]
    fn give_bounded_enforces_count_and_capacity_caps() {
        // Count cap: a third buffer is dropped.
        let mut free: Vec<Vec<f64>> = Vec::new();
        for _ in 0..3 {
            give_bounded(&mut free, vec![0.0; 4], 2, usize::MAX);
        }
        assert_eq!(free.len(), 2);
        // Capacity cap: a one-off monster buffer must not be pinned by a
        // long-lived pool.
        let mut free: Vec<Vec<f64>> = Vec::new();
        give_bounded(&mut free, vec![0.0; 10], 256, 16);
        give_bounded(&mut free, vec![0.0; 10], 256, 16);
        assert_eq!(free.len(), 1, "second buffer exceeds the byte bound");
    }

    #[test]
    fn usize_pool_is_separate() {
        let a = Arena::new();
        let u = a.take_usize(5);
        a.give_usize(u);
        let u2 = a.take_usize(3);
        assert_eq!(a.allocations(), 1);
        assert_eq!(u2.len(), 3);
    }
}
