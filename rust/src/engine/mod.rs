//! Compile-once / execute-many sessions (the engine layer).
//!
//! The convenience entry points in [`sig`](crate::sig) and
//! [`kernel`](crate::kernel) re-derive tensor-algebra layout, re-validate
//! options and freshly allocate every workspace on every call. For serving
//! and training loops that execute the *same* shape class thousands of
//! times, that per-call overhead is pure waste. This module splits the work:
//!
//! * [`Plan::compile`] does all shape-dependent work **once**: validation,
//!   layout tables ([`LevelLayout`] / signature lengths, Horner scratch
//!   sizing, PDE grid geometry, transform output shapes), backend selection
//!   (threaded native vs a PJRT artifact when a runtime is attached), and a
//!   reusable workspace [`Arena`].
//! * `plan.execute(&batch)` then performs **zero shape-dependent heap
//!   allocation** in the steady state — every buffer is checked out of the
//!   arena and returned when the produced [`ExecutionRecord`] drops (the
//!   arena's allocation counter stays flat; asserted in unit tests).
//! * The [`ExecutionRecord`] retains the forward intermediates the paper's
//!   differentiation scheme needs (forward signatures; per-pair Δ matrices
//!   and PDE grids), so [`ExecutionRecord::vjp`] computes exact signature
//!   and kernel gradients without re-running the forward sweep — one API
//!   unifying the previously disjoint `sig::backward` / `kernel::backward`
//!   entry points, bit-for-bit identical to them (Gram/MMD² gradients route
//!   through the same weighted-Gram backward as `try_gram_vjp`; see
//!   [`ExecutionRecord::vjp`] for exactly what is reused).
//! * [`Session`] adds an LRU [`PlanCache`] keyed by (op, shape class), used
//!   by the serving router so repeated traffic classes hit a warm plan.
//!
//! ```no_run
//! use pysiglib::engine::{OpSpec, Plan, ShapeClass};
//! use pysiglib::{PathBatch, SigOptions};
//!
//! let plan = Plan::compile(OpSpec::Sig(SigOptions::new(4)), ShapeClass::uniform(3, 64))?;
//! # let data = vec![0.0; 8 * 64 * 3];
//! let batch = PathBatch::uniform(&data, 8, 64, 3)?;
//! for _ in 0..1000 {
//!     let record = plan.execute(&batch)?; // no shape-dependent allocation
//!     let _sigs = record.values();
//! }
//! # Ok::<(), pysiglib::SigError>(())
//! ```

pub mod arena;
pub mod cache;

pub use arena::Arena;
pub use cache::{CacheStats, PlanCache, Session};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::corpus::{CorpusId, CorpusRegistry};
use crate::kernel::krr::KernelRidge;
use crate::kernel::lanes::{self, LaneScratch};
use crate::kernel::lowrank::{FeatureMap, LowRankFeatures, LowRankRidge, LowRankSpec};
use crate::kernel::scheme::{
    coarse_orders, order2_degenerate, resolve_target_eps, richardson_combine, Scheme,
};
use crate::kernel::{KernelOptions, SolverKind};
use crate::path::{PathBatch, SigError, SigOptions};
use crate::runtime::RuntimeHandle;
use crate::sig::SigMethod;
use crate::tensor::LevelLayout;
use crate::transforms::Transform;
use crate::util::pool::num_threads;

/// Hard cap on the number of f64s a batched output may hold (2^30 = 8 GiB) —
/// a wire-reachable allocation guard, not a practical limitation.
pub(crate) const MAX_BATCH_OUT: usize = 1 << 30;

/// What a plan computes. Carries the same option types as the convenience
/// layer, so `OpSpec::Sig(SigOptions::new(4).transform(..))` reads naturally.
#[derive(Clone, Copy, Debug)]
pub enum OpSpec {
    /// Truncated signatures, one row per path.
    Sig(SigOptions),
    /// Expanded log-signatures, one row per path (always Horner forward).
    LogSig(SigOptions),
    /// Paired signature kernels k(x_i, y_i).
    SigKernel(KernelOptions),
    /// Full Gram matrix k(x_i, y_j).
    Gram(KernelOptions),
    /// Biased MMD² estimator between two path distributions.
    Mmd2(KernelOptions),
    /// Unbiased MMD² estimator (U-statistic; Kxx/Kyy diagonals excluded) —
    /// the two-sample-testing variant.
    Mmd2Unbiased(KernelOptions),
    /// Kernel ridge regression fit (alpha coefficients as output values).
    Krr {
        opts: KernelOptions,
        lambda: f64,
        normalize: bool,
    },
    /// Low-rank Gram matrix Φx·Φyᵀ through an explicit rank-r feature map
    /// (Nyström landmarks drawn from the second batch, or random signature
    /// features) — O(n·r²) against the exact Gram's O(n²·L²).
    GramLowRank {
        opts: KernelOptions,
        lowrank: LowRankSpec,
    },
    /// Low-rank biased MMD²: ‖mean Φx − mean Φy‖². Records retain the
    /// feature matrices; `vjp` maps feature cotangents back to path space
    /// through the exact kernel/signature backward machinery.
    Mmd2LowRank {
        opts: KernelOptions,
        lowrank: LowRankSpec,
    },
    /// Low-rank kernel ridge regression: r×r normal equations in feature
    /// space (weights as output values).
    KrrLowRank {
        opts: KernelOptions,
        lowrank: LowRankSpec,
        lambda: f64,
    },
    /// Cross-Gram `[q, n]` of a query batch against a registered corpus
    /// (`lowrank: Some(..)` reuses the registry's cached corpus feature
    /// matrix; `None` is the exact tiled path). Compile with
    /// [`Plan::compile_corpus`]; executes take the query batch only.
    GramCorpus {
        opts: KernelOptions,
        corpus: CorpusId,
        lowrank: Option<LowRankSpec>,
    },
    /// Biased MMD² between a query batch and a registered corpus. Warm
    /// queries reuse the registry's cached corpus self-Gram (exact) or
    /// feature map + `Φ_c` (low-rank) — only query-side work is solved.
    Mmd2Corpus {
        opts: KernelOptions,
        corpus: CorpusId,
        lowrank: Option<LowRankSpec>,
    },
    /// Exponentially-weighted MMD² between a query *window* and a
    /// registered corpus ([`CorpusRegistry::mmd2_window`]): the query paths
    /// are treated as a time-ordered window whose weights decay by `decay`
    /// per step (newest path weighs most). Exact path only. Like KRR, the
    /// spec carries an `f64` hyperparameter and is compiled fresh rather
    /// than cached.
    Mmd2Window {
        opts: KernelOptions,
        corpus: CorpusId,
        decay: f64,
    },
}

impl OpSpec {
    /// Short name for diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            OpSpec::Sig(_) => "sig",
            OpSpec::LogSig(_) => "logsig",
            OpSpec::SigKernel(_) => "sig_kernel",
            OpSpec::Gram(_) => "gram",
            OpSpec::Mmd2(_) => "mmd2",
            OpSpec::Mmd2Unbiased(_) => "mmd2_unbiased",
            OpSpec::Krr { .. } => "krr",
            OpSpec::GramLowRank { .. } => "gram_lowrank",
            OpSpec::Mmd2LowRank { .. } => "mmd2_lowrank",
            OpSpec::KrrLowRank { .. } => "krr_lowrank",
            OpSpec::GramCorpus { .. } => "gram_corpus",
            OpSpec::Mmd2Corpus { .. } => "mmd2_corpus",
            OpSpec::Mmd2Window { .. } => "mmd2_window",
        }
    }

    /// Cache key for cacheable specs (the KRR variants carry an `f64` and
    /// are compiled fresh each time). The key embeds the option structs
    /// whole, so any field added to
    /// `SigOptions`/`KernelOptions`/`ExecOptions`/`LowRankSpec` later
    /// participates automatically — no hand-maintained digest to drift.
    pub(crate) fn cache_key(&self, shape: ShapeClass, retain: bool) -> Option<PlanKey> {
        let (kind, sig, kernel, lowrank, corpus) = match self {
            OpSpec::Sig(o) => (0u8, Some(*o), None, None, None),
            OpSpec::LogSig(o) => (1, Some(*o), None, None, None),
            OpSpec::SigKernel(k) => (2, None, Some(*k), None, None),
            OpSpec::Gram(k) => (3, None, Some(*k), None, None),
            OpSpec::Mmd2(k) => (4, None, Some(*k), None, None),
            OpSpec::Mmd2Unbiased(k) => (5, None, Some(*k), None, None),
            OpSpec::GramLowRank { opts, lowrank } => (6, None, Some(*opts), Some(*lowrank), None),
            OpSpec::Mmd2LowRank { opts, lowrank } => (7, None, Some(*opts), Some(*lowrank), None),
            OpSpec::GramCorpus {
                opts,
                corpus,
                lowrank,
            } => (8, None, Some(*opts), *lowrank, Some(*corpus)),
            OpSpec::Mmd2Corpus {
                opts,
                corpus,
                lowrank,
            } => (9, None, Some(*opts), *lowrank, Some(*corpus)),
            OpSpec::Krr { .. } | OpSpec::KrrLowRank { .. } | OpSpec::Mmd2Window { .. } => {
                return None
            }
        };
        Some(PlanKey {
            kind,
            sig,
            kernel,
            lowrank,
            corpus,
            shape,
            retain,
        })
    }
}

/// Hashable key of an [`OpSpec`] + [`ShapeClass`] + retention flag — the
/// LRU cache key for shape groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    kind: u8,
    sig: Option<SigOptions>,
    kernel: Option<KernelOptions>,
    lowrank: Option<LowRankSpec>,
    corpus: Option<CorpusId>,
    shape: ShapeClass,
    retain: bool,
}

/// The length profile of a shape class.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LenProfile {
    /// Every path has exactly this many points.
    Uniform(usize),
    /// Ragged batches whose paths have at most this many points.
    Ragged { max_len: usize },
}

/// The shape class a plan is compiled for: path dimension plus length
/// profile. Batch size is *not* part of the class — the same plan serves any
/// batch count (workspaces grow once to the largest batch seen, then stay).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    pub dim: usize,
    pub lens: LenProfile,
}

impl ShapeClass {
    /// Uniform-length class: every path has exactly `len` points.
    pub fn uniform(dim: usize, len: usize) -> ShapeClass {
        ShapeClass {
            dim,
            lens: LenProfile::Uniform(len),
        }
    }

    /// Ragged class: paths of up to `max_len` points.
    pub fn ragged(dim: usize, max_len: usize) -> ShapeClass {
        ShapeClass {
            dim,
            lens: LenProfile::Ragged { max_len },
        }
    }

    /// The tightest class containing `b`.
    pub fn for_batch(b: &PathBatch<'_>) -> ShapeClass {
        match b.uniform_len() {
            Some(l) => ShapeClass::uniform(b.dim(), l),
            None => {
                let max = (0..b.batch()).map(|i| b.len_of(i)).max().unwrap_or(0);
                ShapeClass::ragged(b.dim(), max)
            }
        }
    }

    /// The tightest class containing both sides of a pair op.
    pub fn for_pair(x: &PathBatch<'_>, y: &PathBatch<'_>) -> ShapeClass {
        match (x.uniform_len(), y.uniform_len()) {
            (Some(a), Some(b)) if a == b => ShapeClass::uniform(x.dim(), a),
            _ => {
                let mx = (0..x.batch()).map(|i| x.len_of(i)).max().unwrap_or(0);
                let my = (0..y.batch()).map(|j| y.len_of(j)).max().unwrap_or(0);
                ShapeClass::ragged(x.dim(), mx.max(my))
            }
        }
    }

    /// Widen a ragged class's max length to the next power of two (uniform
    /// classes stay exact) — the cache-key form, so nearby ragged traffic
    /// shares a warm plan. A plan's class is an upper bound; refined-grid
    /// limits are still checked against actual lengths at execute.
    pub fn bucketed(self) -> ShapeClass {
        match self.lens {
            LenProfile::Uniform(_) => self,
            LenProfile::Ragged { max_len } => {
                ShapeClass::ragged(self.dim, max_len.next_power_of_two())
            }
        }
    }
}

/// Execution backend a plan selected at compile time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Threaded native Rust kernels.
    Native,
    /// A PJRT artifact may serve matching batches (native fallback when the
    /// exact batch size has no compiled artifact).
    Pjrt,
}

/// A compiled computation: validated spec + shape class, precomputed layout
/// tables, selected backend, and a reusable workspace arena. `execute` takes
/// `&self` — a plan is shared freely across threads (the router's plan cache
/// hands out `Arc<Plan>`).
pub struct Plan {
    spec: OpSpec,
    shape: ShapeClass,
    retain: bool,
    backend: Backend,
    runtime: Option<Arc<RuntimeHandle>>,
    /// Tensor-algebra layout of the transformed dimension (signature ops).
    layout: Option<LevelLayout>,
    /// Signature row length (signature ops).
    slen: usize,
    /// The registry corpus plans resolve their [`CorpusId`] against.
    corpus_registry: Option<Arc<CorpusRegistry>>,
    /// Lane width of the Gram producers (0 = scalar): resolved at compile
    /// time from the shape class, overridden by `PYSIGLIB_LANES`. Pure
    /// schedule — lane-batched values are bit-identical to scalar ones, so
    /// the width is deliberately *not* part of the plan cache key.
    lanes: usize,
    arena: Arena,
    /// Warm state for low-rank plans: the feature map (and Φy) depend only
    /// on (spec, reference batch y), and training loops execute the same
    /// reference thousands of times — rebuilding the landmark Gram and
    /// re-featurising y per call would redo ~half the PDE work.
    lowrank_warm: Mutex<Option<LowRankWarm>>,
}

/// Cached feature map + reference features of a low-rank plan, valid while
/// the reference batch is byte-identical (checked exactly, not by hash).
struct LowRankWarm {
    y_data: Vec<f64>,
    y_lengths: Vec<usize>,
    map: Arc<FeatureMap>,
    phi_y: Vec<f64>,
}

/// Compile-time validation of a low-rank spec against the shape class: rank
/// and (for random signature features) the sketch's signature length must be
/// sane before any execute touches data.
fn validate_lowrank_spec(
    spec: &LowRankSpec,
    opts: &KernelOptions,
    shape: &ShapeClass,
) -> Result<(), SigError> {
    spec.validate()?;
    if let crate::kernel::lowrank::LowRankMethod::RandomSig { depth, .. } = spec.method {
        let out_dim = opts.exec.transform.out_dim(shape.dim);
        let slen = crate::sig::try_sig_length(out_dim, depth)?;
        // Same bound `RandomSigFeatures::try_new` enforces — a spec that
        // compiles must not fail sketch construction at execute.
        spec.rank
            .checked_mul(slen)
            .filter(|&t| t <= crate::kernel::lowrank::randsig::MAX_SKETCH)
            .ok_or(SigError::TooLarge("random signature sketch"))?;
    }
    Ok(())
}

/// Ops whose execution has no ε-adaptive path (ridge solves, low-rank
/// features, corpus/window queries — their derived state is keyed on a
/// *fixed* grid) must refuse a `target_eps` request at compile rather than
/// silently ignore it.
fn reject_target_eps(k: &KernelOptions, what: &'static str) -> Result<(), SigError> {
    if k.target_eps.get().is_some() {
        return Err(SigError::Invalid(what));
    }
    Ok(())
}

fn validate_kernel_spec(k: &KernelOptions, shape: &ShapeClass) -> Result<(), SigError> {
    k.target_eps.validate()?;
    match shape.lens {
        LenProfile::Uniform(l) if l >= 2 => crate::kernel::check_grid_size(l, l, k),
        // Short or ragged classes: the refined-grid bound is re-checked
        // against the actual lengths at execute; the dyadic orders are
        // checked here so compilation still catches hostile parameters.
        _ => {
            if k.dyadic_x > 32 || k.dyadic_y > 32 {
                return Err(SigError::TooLarge("dyadic refinement order"));
            }
            Ok(())
        }
    }
}

impl Plan {
    /// Compile a record-keeping plan: forward executions retain the
    /// intermediates [`ExecutionRecord::vjp`] needs.
    pub fn compile(spec: OpSpec, shape: ShapeClass) -> Result<Plan, SigError> {
        Plan::compile_custom(spec, shape, true, None)
    }

    /// Compile a forward-only plan: no input copies, no retained grids —
    /// the cheapest steady state for serving. `vjp` on its records errors.
    pub fn compile_forward(spec: OpSpec, shape: ShapeClass) -> Result<Plan, SigError> {
        Plan::compile_custom(spec, shape, false, None)
    }

    /// Full-control compilation: retention flag plus an optional PJRT
    /// runtime for artifact dispatch. Corpus specs are rejected here — they
    /// need a registry; use [`Plan::compile_corpus`].
    pub fn compile_custom(
        spec: OpSpec,
        shape: ShapeClass,
        retain: bool,
        runtime: Option<Arc<RuntimeHandle>>,
    ) -> Result<Plan, SigError> {
        Plan::compile_impl(spec, shape, retain, runtime, None)
    }

    /// Compile a corpus-query plan ([`OpSpec::GramCorpus`] /
    /// [`OpSpec::Mmd2Corpus`] / [`OpSpec::Mmd2Window`]): the shape class
    /// describes the **query**
    /// side; the corpus id resolves against `registry` at execute time, so
    /// a cached plan stays valid across appends. Corpus plans are
    /// forward-only (their corpus-side state lives in the registry, not on
    /// the record), so `vjp` on their records errors.
    pub fn compile_corpus(
        spec: OpSpec,
        shape: ShapeClass,
        registry: Arc<CorpusRegistry>,
    ) -> Result<Plan, SigError> {
        if !matches!(
            spec,
            OpSpec::GramCorpus { .. } | OpSpec::Mmd2Corpus { .. } | OpSpec::Mmd2Window { .. }
        ) {
            return Err(SigError::Invalid(
                "compile_corpus takes a GramCorpus / Mmd2Corpus / Mmd2Window spec",
            ));
        }
        Plan::compile_impl(spec, shape, false, None, Some(registry))
    }

    fn compile_impl(
        spec: OpSpec,
        shape: ShapeClass,
        retain: bool,
        runtime: Option<Arc<RuntimeHandle>>,
        corpus_registry: Option<Arc<CorpusRegistry>>,
    ) -> Result<Plan, SigError> {
        if shape.dim == 0 {
            return Err(SigError::ZeroDim);
        }
        if let LenProfile::Uniform(l) = shape.lens {
            if l == 0 {
                return Err(SigError::EmptyPath);
            }
        }
        let mut layout = None;
        let mut slen = 0;
        match &spec {
            OpSpec::Sig(o) | OpSpec::LogSig(o) => {
                o.validate()?;
                let od = o.exec.transform.out_dim(shape.dim);
                slen = crate::sig::try_sig_length(od, o.depth)?;
                layout = Some(LevelLayout::new(od, o.depth));
            }
            OpSpec::SigKernel(k) | OpSpec::Gram(k) | OpSpec::Mmd2(k) | OpSpec::Mmd2Unbiased(k) => {
                validate_kernel_spec(k, &shape)?;
            }
            OpSpec::Krr { opts, lambda, .. } => {
                validate_kernel_spec(opts, &shape)?;
                reject_target_eps(opts, "target_eps is not supported for ridge plans")?;
                if !(*lambda > 0.0) {
                    return Err(SigError::NonFinite("ridge λ must be positive"));
                }
            }
            OpSpec::GramLowRank { opts, lowrank } | OpSpec::Mmd2LowRank { opts, lowrank } => {
                validate_kernel_spec(opts, &shape)?;
                reject_target_eps(opts, "target_eps is not supported for low-rank plans")?;
                validate_lowrank_spec(lowrank, opts, &shape)?;
            }
            OpSpec::KrrLowRank {
                opts,
                lowrank,
                lambda,
            } => {
                validate_kernel_spec(opts, &shape)?;
                reject_target_eps(opts, "target_eps is not supported for low-rank plans")?;
                validate_lowrank_spec(lowrank, opts, &shape)?;
                if !(*lambda > 0.0) {
                    return Err(SigError::NonFinite("ridge λ must be positive"));
                }
            }
            OpSpec::GramCorpus {
                opts,
                corpus,
                lowrank,
            }
            | OpSpec::Mmd2Corpus {
                opts,
                corpus,
                lowrank,
            } => {
                validate_kernel_spec(opts, &shape)?;
                reject_target_eps(opts, "target_eps is not supported for corpus plans")?;
                if let Some(lr) = lowrank {
                    validate_lowrank_spec(lr, opts, &shape)?;
                }
                let Some(reg) = corpus_registry.as_ref() else {
                    return Err(SigError::Invalid(
                        "corpus plans need a registry; compile via Plan::compile_corpus",
                    ));
                };
                match reg.dim_of(*corpus) {
                    None => return Err(SigError::Invalid("unknown corpus id")),
                    Some(d) if d != shape.dim => {
                        return Err(SigError::DimMismatch {
                            left: shape.dim,
                            right: d,
                        })
                    }
                    Some(_) => {}
                }
            }
            OpSpec::Mmd2Window {
                opts,
                corpus,
                decay,
            } => {
                validate_kernel_spec(opts, &shape)?;
                reject_target_eps(opts, "target_eps is not supported for window plans")?;
                if !(decay.is_finite() && *decay > 0.0 && *decay <= 1.0) {
                    return Err(SigError::NonFinite("window decay must lie in (0, 1]"));
                }
                let Some(reg) = corpus_registry.as_ref() else {
                    return Err(SigError::Invalid(
                        "corpus plans need a registry; compile via Plan::compile_corpus",
                    ));
                };
                match reg.dim_of(*corpus) {
                    None => return Err(SigError::Invalid("unknown corpus id")),
                    Some(d) if d != shape.dim => {
                        return Err(SigError::DimMismatch {
                            left: shape.dim,
                            right: d,
                        })
                    }
                    Some(_) => {}
                }
            }
        }
        let backend = match (&runtime, &spec, shape.lens) {
            (Some(_), OpSpec::Sig(o), LenProfile::Uniform(_))
                if o.exec.transform == Transform::None =>
            {
                Backend::Pjrt
            }
            (Some(_), OpSpec::SigKernel(k), LenProfile::Uniform(_))
                if k.dyadic_x == 0
                    && k.dyadic_y == 0
                    && k.exec.transform == Transform::None
                    && k.scheme == Scheme::Order1
                    && k.target_eps.get().is_none() =>
            {
                Backend::Pjrt
            }
            _ => Backend::Native,
        };
        // Lane width for the Gram producers (signature ops have no PDE, and
        // blocked-solver specs keep the scalar schedule — width 0 here also
        // keeps their worker scratch scalar-sized): uniform classes default
        // to W = 8, ragged to W = 4, both overridable with PYSIGLIB_LANES
        // (0 = scalar). Chosen here — at compile time — so a plan's
        // schedule is stable across executes.
        let lanes = match &spec {
            OpSpec::Sig(_) | OpSpec::LogSig(_) => 0,
            OpSpec::SigKernel(k)
            | OpSpec::Gram(k)
            | OpSpec::Mmd2(k)
            | OpSpec::Mmd2Unbiased(k)
            | OpSpec::Krr { opts: k, .. }
            | OpSpec::GramLowRank { opts: k, .. }
            | OpSpec::Mmd2LowRank { opts: k, .. }
            | OpSpec::KrrLowRank { opts: k, .. }
            | OpSpec::GramCorpus { opts: k, .. }
            | OpSpec::Mmd2Corpus { opts: k, .. }
            | OpSpec::Mmd2Window { opts: k, .. } => {
                if k.solver == SolverKind::Blocked {
                    0
                } else {
                    lanes::lane_width_for(matches!(shape.lens, LenProfile::Uniform(_)))
                }
            }
        };
        Ok(Plan {
            spec,
            shape,
            retain,
            backend,
            runtime,
            layout,
            slen,
            corpus_registry,
            lanes,
            arena: Arena::new(),
            lowrank_warm: Mutex::new(None),
        })
    }

    pub fn spec(&self) -> &OpSpec {
        &self.spec
    }

    pub fn shape(&self) -> ShapeClass {
        self.shape
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Lane width the plan's Gram producers use (0 = scalar).
    pub fn lane_width(&self) -> usize {
        self.lanes
    }

    /// Override the lane width (snapped to 0/4/8). Values are bit-identical
    /// for every width, so this is a scheduling knob — used by the property
    /// tests and benches to pin the schedule without touching the
    /// environment.
    pub fn with_lane_width(mut self, width: usize) -> Plan {
        self.lanes = lanes::normalize_lane_width(width);
        self
    }

    /// Output row length of a signature / log-signature plan (0 for other
    /// ops) — precomputed at compilation, so callers chunking batched
    /// output need not re-derive it.
    pub fn row_len(&self) -> usize {
        self.slen
    }

    /// Fresh heap allocations the workspace arena has performed — flat
    /// across repeated executions on same-shape inputs.
    pub fn allocations(&self) -> u64 {
        self.arena.allocations()
    }

    /// Does the input batch belong to this plan's shape class?
    fn check_batch(&self, b: &PathBatch<'_>) -> Result<(), SigError> {
        if b.dim() != self.shape.dim {
            return Err(SigError::DimMismatch {
                left: b.dim(),
                right: self.shape.dim,
            });
        }
        match self.shape.lens {
            LenProfile::Uniform(l) => {
                if !b.is_empty() && b.uniform_len() != Some(l) {
                    return Err(SigError::Invalid(
                        "batch does not match the plan's uniform length class",
                    ));
                }
            }
            LenProfile::Ragged { max_len } => {
                for i in 0..b.batch() {
                    if b.len_of(i) > max_len {
                        return Err(SigError::Invalid(
                            "path exceeds the plan's maximum length class",
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Execute a single-batch plan: signatures / log-signatures, or a
    /// corpus query (the batch is the query side; the corpus lives in the
    /// plan's registry).
    pub fn execute(&self, x: &PathBatch<'_>) -> Result<ExecutionRecord, SigError> {
        let (opts, log) = match &self.spec {
            OpSpec::Sig(o) => (*o, false),
            OpSpec::LogSig(o) => (*o, true),
            OpSpec::GramCorpus {
                opts,
                corpus,
                lowrank,
            } => return self.exec_corpus(x, opts, *corpus, lowrank.as_ref(), true),
            OpSpec::Mmd2Corpus {
                opts,
                corpus,
                lowrank,
            } => return self.exec_corpus(x, opts, *corpus, lowrank.as_ref(), false),
            OpSpec::Mmd2Window {
                opts,
                corpus,
                decay,
            } => {
                self.check_batch(x)?;
                let reg = self
                    .corpus_registry
                    .as_ref()
                    .ok_or(SigError::Invalid("corpus plan has no registry attached"))?;
                let v = reg.mmd2_window(*corpus, x, opts, *decay)?;
                return Ok(self.record(vec![v], None, None, RecordState::None, false));
            }
            _ => {
                return Err(SigError::Invalid(
                    "this plan takes a pair of batches; use execute_pair / execute_fit",
                ))
            }
        };
        self.check_batch(x)?;
        let b = x.batch();
        let slen = self.slen;
        let total = b
            .checked_mul(slen)
            .filter(|&t| t <= MAX_BATCH_OUT)
            .ok_or(SigError::TooLarge(if log {
                "batched log-signature output"
            } else {
                "batched signature output"
            }))?;
        // Artifacts return no intermediates, so the PJRT route only serves
        // forward-only plans — a retained plan must keep its vjp contract.
        if self.backend == Backend::Pjrt && !log && !self.retain {
            if let Some(values) = self.try_pjrt_sig(x)? {
                return Ok(self.record(values, Some(x), None, RecordState::None, false));
            }
        }
        let mut out = self.arena.take(total);
        let layout = self.layout.as_ref().expect("sig plan has a layout");
        let method = if log { SigMethod::Horner } else { opts.method };
        let scratch_len = crate::sig::sig_scratch_len(layout, method);
        let (od, tlen) = (layout.dim, layout.total());
        let transform = opts.exec.transform;
        {
            let base = out.as_mut_ptr() as usize;
            let arena = &self.arena;
            run_items(
                opts.exec.parallel,
                b,
                || SigScratch::checkout(arena, od, scratch_len, if log { tlen } else { 0 }),
                |i, sc: &mut SigScratch| {
                    // SAFETY: row i is out[i*slen..(i+1)*slen], written by
                    // exactly one item; `out` outlives the scope.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut((base as *mut f64).add(i * slen), slen)
                    };
                    let p = x.path(i);
                    if log {
                        crate::sig::signature_into(
                            p.data(),
                            p.len(),
                            p.dim(),
                            method,
                            transform,
                            layout,
                            &mut sc.sig,
                            &mut sc.z,
                            &mut sc.s,
                        );
                        crate::tensor::tensor_log_into(
                            layout,
                            &sc.sig,
                            row,
                            &mut sc.lx,
                            &mut sc.lacc,
                            &mut sc.lnext,
                        );
                    } else {
                        crate::sig::signature_into(
                            p.data(),
                            p.len(),
                            p.dim(),
                            method,
                            transform,
                            layout,
                            row,
                            &mut sc.z,
                            &mut sc.s,
                        );
                    }
                },
            );
        }
        Ok(self.record(out, Some(x), None, RecordState::None, self.retain))
    }

    /// Execute a paired-batch kernel / Gram / MMD² plan.
    pub fn execute_pair(
        &self,
        x: &PathBatch<'_>,
        y: &PathBatch<'_>,
    ) -> Result<ExecutionRecord, SigError> {
        let k = match &self.spec {
            OpSpec::SigKernel(k) | OpSpec::Gram(k) | OpSpec::Mmd2(k) | OpSpec::Mmd2Unbiased(k) => {
                *k
            }
            OpSpec::GramLowRank { opts, .. } | OpSpec::Mmd2LowRank { opts, .. } => *opts,
            _ => {
                return Err(SigError::Invalid(
                    "this plan takes a single batch; use execute / execute_fit",
                ))
            }
        };
        if x.dim() != y.dim() {
            return Err(SigError::DimMismatch {
                left: x.dim(),
                right: y.dim(),
            });
        }
        self.check_batch(x)?;
        self.check_batch(y)?;
        // Grid sizes are monotone in path length: the longest (x, y) pair
        // bounds every pair, so per-pair solves below cannot fail.
        let mx = (0..x.batch()).map(|i| x.len_of(i)).max().unwrap_or(0);
        let my = (0..y.batch()).map(|j| y.len_of(j)).max().unwrap_or(0);
        if mx >= 2 && my >= 2 {
            crate::kernel::check_grid_size(mx, my, &k)?;
        }
        match self.spec {
            OpSpec::SigKernel(_) => self.exec_paired_kernel(x, y, &k),
            OpSpec::Gram(_) => self.exec_gram(x, y, &k),
            OpSpec::Mmd2(_) => self.exec_mmd2(x, y, &k, true),
            OpSpec::Mmd2Unbiased(_) => self.exec_mmd2(x, y, &k, false),
            OpSpec::GramLowRank { lowrank, .. } => self.exec_lowrank(x, y, &k, &lowrank, true),
            OpSpec::Mmd2LowRank { lowrank, .. } => self.exec_lowrank(x, y, &k, &lowrank, false),
            _ => unreachable!(),
        }
    }

    /// Execute a KRR plan (exact or low-rank): fit coefficients on `x` with
    /// targets `y`.
    pub fn execute_fit(&self, x: &PathBatch<'_>, y: &[f64]) -> Result<ExecutionRecord, SigError> {
        match &self.spec {
            OpSpec::Krr {
                opts,
                lambda,
                normalize,
            } => {
                self.check_batch(x)?;
                let model = KernelRidge::fit_impl(x, y, *lambda, *normalize, opts)?;
                let mut values = self.arena.take(model.alpha().len());
                values.copy_from_slice(model.alpha());
                Ok(self.record(
                    values,
                    Some(x),
                    None,
                    RecordState::Krr(Box::new(model)),
                    self.retain,
                ))
            }
            OpSpec::KrrLowRank {
                opts,
                lowrank,
                lambda,
            } => {
                self.check_batch(x)?;
                // Landmarks for the feature map come from the training batch
                // itself (the only data a fit sees).
                let map = FeatureMap::try_build(lowrank, opts, x)?;
                let model = LowRankRidge::try_fit(map, x, y, *lambda)?;
                let mut values = self.arena.take(model.weights().len());
                values.copy_from_slice(model.weights());
                Ok(self.record(
                    values,
                    Some(x),
                    None,
                    RecordState::KrrLowRank(Box::new(model)),
                    self.retain,
                ))
            }
            _ => Err(SigError::Invalid("only KRR plans take targets")),
        }
    }

    fn exec_paired_kernel(
        &self,
        x: &PathBatch<'_>,
        y: &PathBatch<'_>,
        k: &KernelOptions,
    ) -> Result<ExecutionRecord, SigError> {
        if x.batch() != y.batch() {
            return Err(SigError::BatchMismatch {
                left: x.batch(),
                right: y.batch(),
            });
        }
        let b = x.batch();
        if self.backend == Backend::Pjrt && !self.retain {
            if let Some(values) = self.try_pjrt_kernel(x, y)? {
                return Ok(self.record(values, Some(x), Some(y), RecordState::None, false));
            }
        }
        // Resolve an ε-adaptive request against this batch. Resolution is
        // deterministic and idempotent, so `vjp_kernel` re-resolving from
        // the same inputs lands on the same (scheme, λ).
        let resolved = resolve_target_eps(x, y, k)?;
        let k = &resolved;
        let tr = k.exec.transform;
        let dim = x.dim();
        let (lam1, lam2) = (k.dyadic_x, k.dyadic_y);
        // Non-degenerate Order2 retains TWO grids per pair — fine at
        // (λ1, λ2) and coarse at the coarsened orders, concatenated — so the
        // backward can run both adjoint passes without a forward re-solve.
        let order2 = k.scheme == Scheme::Order2 && !order2_degenerate(lam1, lam2);
        let retain = self.retain;
        // Per-pair geometry: transformed Δ dims, flat offsets for the shared
        // Δ (and, when retaining, grid) buffers.
        let mut dims = self.arena.take_usize(2 * b);
        let mut delta_off = self.arena.take_usize(b + 1);
        let mut grid_off = self.arena.take_usize(b + 1);
        let (mut dtot, mut gtot) = (0usize, 0usize);
        let (mut max_lx, mut max_ly, mut max_cols) = (0usize, 0usize, 0usize);
        for i in 0..b {
            let (lx, ly) = (x.len_of(i), y.len_of(i));
            delta_off[i] = dtot;
            grid_off[i] = gtot;
            if lx < 2 || ly < 2 {
                continue; // dims stay 0: degenerate pair, k = 1
            }
            let m = tr.out_len(lx) - 1;
            let n = tr.out_len(ly) - 1;
            dims[2 * i] = m;
            dims[2 * i + 1] = n;
            dtot = dtot
                .checked_add(m * n)
                .filter(|&t| t <= MAX_BATCH_OUT)
                .ok_or(SigError::TooLarge("kernel Δ workspace"))?;
            if retain {
                // Same 8 GiB guard as every other wire-reachable allocation:
                // a gradient frame retains ALL pairs' refined grids at once
                // (the price of Algorithm 4 without forward re-solves), so
                // the total — not just each pair — must stay bounded.
                gtot = gtot
                    .checked_add(((m << lam1) + 1) * ((n << lam2) + 1))
                    .filter(|&t| t <= MAX_BATCH_OUT)
                    .ok_or(SigError::TooLarge("retained PDE grids"))?;
                if order2 {
                    let (c1, c2) = coarse_orders(lam1, lam2);
                    gtot = gtot
                        .checked_add(((m << c1) + 1) * ((n << c2) + 1))
                        .filter(|&t| t <= MAX_BATCH_OUT)
                        .ok_or(SigError::TooLarge("retained PDE grids"))?;
                }
            }
            max_lx = max_lx.max(lx);
            max_ly = max_ly.max(ly);
            max_cols = max_cols.max(n << lam2);
        }
        delta_off[b] = dtot;
        grid_off[b] = gtot;
        let mut out = self.arena.take(b);
        let mut deltas = self.arena.take(dtot);
        let mut grids = self.arena.take(gtot);
        {
            let out_base = out.as_mut_ptr() as usize;
            let delta_base = deltas.as_mut_ptr() as usize;
            let grid_base = grids.as_mut_ptr() as usize;
            let arena = &self.arena;
            let needs_base = matches!(tr, Transform::LeadLag | Transform::LeadLagTimeAug);
            let (dims, delta_off, grid_off) = (&dims, &delta_off, &grid_off);
            run_items(
                k.exec.parallel,
                b,
                || {
                    KernScratch::checkout(
                        arena,
                        max_lx,
                        max_ly,
                        dim,
                        needs_base,
                        if retain { 0 } else { max_cols + 1 },
                    )
                },
                |i, sc: &mut KernScratch| {
                    // SAFETY: slot i of `out` and the [delta_off[i],
                    // delta_off[i+1]) / [grid_off[i], grid_off[i+1]) regions
                    // are written by exactly one item (offsets are
                    // non-decreasing); the buffers outlive the scope.
                    let slot = unsafe {
                        std::slice::from_raw_parts_mut((out_base as *mut f64).add(i), 1)
                    };
                    let (lx, ly) = (x.len_of(i), y.len_of(i));
                    if lx < 2 || ly < 2 {
                        slot[0] = 1.0;
                        return;
                    }
                    let (m, n) = (dims[2 * i], dims[2 * i + 1]);
                    let delta = unsafe {
                        std::slice::from_raw_parts_mut(
                            (delta_base as *mut f64).add(delta_off[i]),
                            m * n,
                        )
                    };
                    let written = crate::kernel::delta::delta_matrix_into(
                        x.values_of(i),
                        y.values_of(i),
                        lx,
                        ly,
                        dim,
                        tr,
                        &mut sc.dx,
                        &mut sc.dy,
                        &mut sc.base,
                        delta,
                    );
                    debug_assert_eq!(written, (m, n));
                    if retain {
                        let glen = grid_off[i + 1] - grid_off[i];
                        let grid = unsafe {
                            std::slice::from_raw_parts_mut(
                                (grid_base as *mut f64).add(grid_off[i]),
                                glen,
                            )
                        };
                        // Fine grid first; under non-degenerate Order2 the
                        // coarse grid follows in the same retained region.
                        let gf = if order2 {
                            ((m << lam1) + 1) * ((n << lam2) + 1)
                        } else {
                            glen
                        };
                        let (gfine, gcoarse) = grid.split_at_mut(gf);
                        crate::kernel::solver::solve_pde_grid_into(delta, m, n, lam1, lam2, gfine);
                        if order2 {
                            let (c1, c2) = coarse_orders(lam1, lam2);
                            crate::kernel::solver::solve_pde_grid_into(
                                delta, m, n, c1, c2, gcoarse,
                            );
                        }
                        slot[0] = match k.solver {
                            SolverKind::Row => {
                                let fine = gfine[gf - 1];
                                if order2 {
                                    richardson_combine(fine, gcoarse[gcoarse.len() - 1])
                                } else {
                                    fine
                                }
                            }
                            SolverKind::Blocked => crate::kernel::blocked::solve_pde_blocked_scheme(
                                delta, m, n, lam1, lam2, k.scheme,
                            ),
                        };
                    } else {
                        slot[0] = match k.solver {
                            SolverKind::Row => crate::kernel::solver::solve_pde_scheme(
                                delta,
                                m,
                                n,
                                lam1,
                                lam2,
                                k.scheme,
                                &mut sc.prev,
                                &mut sc.cur,
                            ),
                            SolverKind::Blocked => crate::kernel::blocked::solve_pde_blocked_scheme(
                                delta, m, n, lam1, lam2, k.scheme,
                            ),
                        };
                    }
                },
            );
        }
        let state = if retain {
            RecordState::KernelPairs {
                deltas,
                delta_off,
                grids,
                grid_off,
                dims,
            }
        } else {
            self.arena.give(deltas);
            self.arena.give(grids);
            self.arena.give_usize(dims);
            self.arena.give_usize(delta_off);
            self.arena.give_usize(grid_off);
            RecordState::None
        };
        Ok(self.record(out, Some(x), Some(y), state, retain))
    }

    /// Gram values into a preallocated `[bx, by]` buffer (shared by the Gram
    /// and MMD² ops). Inputs must already be validated.
    ///
    /// Work items are row strips (`COL_CHUNK` columns of one x-row); inside
    /// a strip [`lanes::solve_gram_row`] groups same-shape columns into lane
    /// groups of the plan's width and sweeps W kernels at once, finishing
    /// the remainder scalar — bit-identical to the per-entry path for every
    /// width, since each lane runs the scalar FP sequence.
    fn gram_values_into(
        &self,
        x: &PathBatch<'_>,
        y: &PathBatch<'_>,
        k: &KernelOptions,
        out: &mut [f64],
    ) {
        // Columns per work item: wide enough to fill several W = 8 lane
        // groups per claim. Skinny Grams (fewer rows than workers — e.g. a
        // single-query KRR predict against a large support set) shrink the
        // chunk so bx × chunks still covers the worker count, floored at
        // the lane width so each chunk can hold at least one full group.
        const MAX_COL_CHUNK: usize = 64;
        let (bx, by) = (x.batch(), y.batch());
        debug_assert_eq!(out.len(), bx * by);
        if bx * by == 0 {
            return;
        }
        let tr = k.exec.transform;
        let dim = x.dim();
        let lam2 = k.dyadic_y;
        let width = self.lanes;
        let mx = (0..bx).map(|i| x.len_of(i)).max().unwrap_or(0);
        let my = (0..by).map(|j| y.len_of(j)).max().unwrap_or(0);
        let nt = num_threads().max(1);
        let col_chunk = if bx >= nt {
            MAX_COL_CHUNK
        } else {
            let chunks_per_row = nt.div_ceil(bx);
            by.div_ceil(chunks_per_row)
                .max(width.max(1))
                .min(MAX_COL_CHUNK)
        };
        let col_chunks = by.div_ceil(col_chunk);
        let out_base = out.as_mut_ptr() as usize;
        let arena = &self.arena;
        run_items(
            k.exec.parallel,
            bx * col_chunks,
            || GramScratch::checkout(arena, mx, my, dim, tr, lam2, width, col_chunk.min(by)),
            |p, sc: &mut GramScratch| {
                let (i, c) = (p / col_chunks, p % col_chunks);
                let j0 = c * col_chunk;
                let j1 = (j0 + col_chunk).min(by);
                // SAFETY: strip (i, j0..j1) is written by exactly one item
                // (items partition the [bx, by] index space) and `out`
                // outlives the scope inside `run_items`.
                let row = unsafe {
                    std::slice::from_raw_parts_mut(
                        (out_base as *mut f64).add(i * by + j0),
                        j1 - j0,
                    )
                };
                lanes::solve_gram_row(x, i, y, j0..j1, k, width, &mut sc.inner, row);
            },
        );
    }

    fn exec_gram(
        &self,
        x: &PathBatch<'_>,
        y: &PathBatch<'_>,
        k: &KernelOptions,
    ) -> Result<ExecutionRecord, SigError> {
        let resolved = resolve_target_eps(x, y, k)?;
        let k = &resolved;
        let total = x
            .batch()
            .checked_mul(y.batch())
            .filter(|&t| t <= MAX_BATCH_OUT)
            .ok_or(SigError::TooLarge("gram output"))?;
        let mut out = self.arena.take(total);
        self.gram_values_into(x, y, k, &mut out);
        Ok(self.record(out, Some(x), Some(y), RecordState::None, self.retain))
    }

    fn exec_mmd2(
        &self,
        x: &PathBatch<'_>,
        y: &PathBatch<'_>,
        k: &KernelOptions,
        biased: bool,
    ) -> Result<ExecutionRecord, SigError> {
        // The V-statistic is defined from one path per side; the U-statistic
        // divides by b(b−1) and needs two.
        let need = if biased { 1 } else { 2 };
        let (bx, by) = (x.batch(), y.batch());
        if bx < need || by < need {
            return Err(SigError::InsufficientBatch {
                need,
                got: bx.min(by),
            });
        }
        // Same allocation guard as the Gram op — three Gram matrices back
        // one MMD² value.
        let resolved = resolve_target_eps(x, y, k)?;
        let k = &resolved;
        let gram_len = |a: usize, b: usize| -> Result<usize, SigError> {
            a.checked_mul(b)
                .filter(|&t| t <= MAX_BATCH_OUT)
                .ok_or(SigError::TooLarge("mmd2 gram matrices"))
        };
        let mut kxx = self.arena.take(gram_len(bx, bx)?);
        let mut kxy = self.arena.take(gram_len(bx, by)?);
        let mut kyy = self.arena.take(gram_len(by, by)?);
        self.gram_values_into(x, x, k, &mut kxx);
        self.gram_values_into(x, y, k, &mut kxy);
        self.gram_values_into(y, y, k, &mut kyy);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let off_mean = |v: &[f64], b: usize| {
            let total: f64 = v.iter().sum();
            let diag: f64 = (0..b).map(|i| v[i * b + i]).sum();
            (total - diag) / (b * (b - 1)) as f64
        };
        let value = if biased {
            mean(&kxx) - 2.0 * mean(&kxy) + mean(&kyy)
        } else {
            off_mean(&kxx, bx) - 2.0 * mean(&kxy) + off_mean(&kyy, by)
        };
        let mut values = self.arena.take(1);
        values[0] = value;
        let state = if self.retain {
            RecordState::Mmd2 { kxx, kxy, kyy }
        } else {
            self.arena.give(kxx);
            self.arena.give(kxy);
            self.arena.give(kyy);
            RecordState::None
        };
        Ok(self.record(values, Some(x), Some(y), state, self.retain))
    }

    /// Execute a low-rank Gram / MMD² plan: build the feature map the spec
    /// describes (Nyström landmarks drawn from `y`, the reference batch, so
    /// x-gradients are exact; random signature sketches from the seed
    /// alone), compute both feature matrices and reduce them.
    fn exec_lowrank(
        &self,
        x: &PathBatch<'_>,
        y: &PathBatch<'_>,
        k: &KernelOptions,
        spec: &LowRankSpec,
        gram: bool,
    ) -> Result<ExecutionRecord, SigError> {
        let (bx, by) = (x.batch(), y.batch());
        if !gram && (bx == 0 || by == 0) {
            return Err(SigError::InsufficientBatch {
                need: 1,
                got: bx.min(by),
            });
        }
        // Feature matrices are wire-reachable allocations: same 8 GiB guard
        // as every batched output.
        for b in [bx, by] {
            b.checked_mul(spec.rank)
                .filter(|&t| t <= MAX_BATCH_OUT)
                .ok_or(SigError::TooLarge("low-rank feature matrix"))?;
        }
        // Warm path: the map and Φy depend only on (spec, y) — reuse them
        // across executes against the same reference batch (exact equality
        // check; a changed y rebuilds). The build happens outside the lock;
        // a racing duplicate build is harmless (last one wins), as in the
        // plan cache.
        let cached = {
            let warm = crate::util::sync::lock_unpoisoned(&self.lowrank_warm);
            warm.as_ref()
                .filter(|w| {
                    w.y_lengths.len() == by
                        && (0..by).all(|i| w.y_lengths[i] == y.len_of(i))
                        && w.y_data == y.data()
                })
                .map(|w| (w.map.clone(), w.phi_y.clone()))
        };
        let (map, phi_y) = match cached {
            Some(v) => v,
            None => {
                let map = Arc::new(FeatureMap::try_build(spec, k, y)?);
                let phi_y = map.try_features(y)?;
                *crate::util::sync::lock_unpoisoned(&self.lowrank_warm) = Some(LowRankWarm {
                    y_data: y.data().to_vec(),
                    y_lengths: (0..by).map(|i| y.len_of(i)).collect(),
                    map: map.clone(),
                    phi_y: phi_y.clone(),
                });
                (map, phi_y)
            }
        };
        let r = map.rank();
        let phi_x = map.try_features(x)?;
        let values = if gram {
            let total = bx
                .checked_mul(by)
                .filter(|&t| t <= MAX_BATCH_OUT)
                .ok_or(SigError::TooLarge("gram output"))?;
            let mut out = self.arena.take(total);
            crate::util::linalg::gemm_nt(bx, r, by, &phi_x, &phi_y, &mut out);
            out
        } else {
            let mx = crate::kernel::lowrank::feature_mean(&phi_x, bx, r);
            let my = crate::kernel::lowrank::feature_mean(&phi_y, by, r);
            let mut out = self.arena.take(1);
            out[0] = mx
                .iter()
                .zip(my.iter())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            out
        };
        let state = if self.retain {
            RecordState::LowRank { map, phi_x, phi_y }
        } else {
            self.arena.give(phi_x);
            self.arena.give(phi_y);
            RecordState::None
        };
        Ok(self.record(values, Some(x), Some(y), state, self.retain))
    }

    /// Execute a corpus-query plan: the registry serves the corpus-side
    /// state (cached self-Gram tiles / feature matrices), only query-side
    /// work runs here. Corpus records are forward-only.
    fn exec_corpus(
        &self,
        q: &PathBatch<'_>,
        k: &KernelOptions,
        id: CorpusId,
        lowrank: Option<&LowRankSpec>,
        gram: bool,
    ) -> Result<ExecutionRecord, SigError> {
        self.check_batch(q)?;
        let reg = self
            .corpus_registry
            .as_ref()
            .ok_or(SigError::Invalid("corpus plan has no registry attached"))?;
        let values = if gram {
            reg.gram_query(id, q, k, lowrank)?
        } else {
            vec![reg.mmd2_query(id, q, k, lowrank)?]
        };
        Ok(self.record(values, None, None, RecordState::None, false))
    }

    /// Build the record, copying inputs (through the arena) when retaining.
    fn record(
        &self,
        values: Vec<f64>,
        x: Option<&PathBatch<'_>>,
        y: Option<&PathBatch<'_>>,
        state: RecordState,
        retain: bool,
    ) -> ExecutionRecord {
        let copy = |b: Option<&PathBatch<'_>>| -> (Vec<f64>, Vec<usize>) {
            match b {
                Some(b) if retain => {
                    let mut data = self.arena.take(b.data().len());
                    data.copy_from_slice(b.data());
                    let mut lens = self.arena.take_usize(b.batch());
                    for i in 0..b.batch() {
                        lens[i] = b.len_of(i);
                    }
                    (data, lens)
                }
                _ => (Vec::new(), Vec::new()),
            }
        };
        let (x_data, x_lengths) = copy(x);
        let (y_data, y_lengths) = copy(y);
        ExecutionRecord {
            spec: self.spec,
            dim: self.shape.dim,
            slen: self.slen,
            retain,
            lanes: self.lanes,
            arena: self.arena.clone(),
            values,
            x_data,
            x_lengths,
            y_data,
            y_lengths,
            state,
        }
    }

    /// Try the PJRT artifact route for a signature batch. `Ok(None)` means
    /// "no artifact for this exact batch — use the native path"; runtime
    /// failures are surfaced, not swallowed.
    fn try_pjrt_sig(&self, x: &PathBatch<'_>) -> Result<Option<Vec<f64>>, SigError> {
        let Some(rt) = self.runtime.as_ref() else {
            return Ok(None);
        };
        let (LenProfile::Uniform(len), OpSpec::Sig(o)) = (self.shape.lens, &self.spec) else {
            return Ok(None);
        };
        let name = format!("signature_b{}_l{len}_d{}_n{}", x.batch(), self.shape.dim, o.depth);
        if rt.info(&name).is_none() {
            return Ok(None);
        }
        let xs: Vec<f32> = x.data().iter().map(|&v| v as f32).collect();
        self.run_pjrt(rt, &name, vec![xs], x.batch() * self.slen)
            .map(Some)
    }

    fn try_pjrt_kernel(
        &self,
        x: &PathBatch<'_>,
        y: &PathBatch<'_>,
    ) -> Result<Option<Vec<f64>>, SigError> {
        let Some(rt) = self.runtime.as_ref() else {
            return Ok(None);
        };
        let LenProfile::Uniform(len) = self.shape.lens else {
            return Ok(None);
        };
        let name = format!("sigkernel_b{}_l{len}_d{}", x.batch(), self.shape.dim);
        if rt.info(&name).is_none() {
            return Ok(None);
        }
        let xs: Vec<f32> = x.data().iter().map(|&v| v as f32).collect();
        let ys: Vec<f32> = y.data().iter().map(|&v| v as f32).collect();
        self.run_pjrt(rt, &name, vec![xs, ys], x.batch()).map(Some)
    }

    /// `expected_len` is the plan's exact output length for this batch —
    /// a mismatching artifact must surface as an error, not as misaligned
    /// rows downstream.
    fn run_pjrt(
        &self,
        rt: &RuntimeHandle,
        name: &str,
        inputs: Vec<Vec<f32>>,
        expected_len: usize,
    ) -> Result<Vec<f64>, SigError> {
        let outputs = rt
            .execute_f32(name, inputs)
            .map_err(|e| SigError::Backend(format!("pjrt artifact '{name}': {e}")))?;
        let flat = outputs.first().ok_or_else(|| {
            SigError::Backend(format!("pjrt artifact '{name}' returned no outputs"))
        })?;
        if flat.len() != expected_len {
            return Err(SigError::Backend(format!(
                "pjrt artifact '{name}' returned {} values, expected {expected_len}",
                flat.len()
            )));
        }
        let mut out = self.arena.take(flat.len());
        for (o, &v) in out.iter_mut().zip(flat.iter()) {
            *o = v as f64;
        }
        Ok(out)
    }
}

/// Per-worker scratch for signature plans; buffers return to the arena on
/// drop (worker exit), so a repeat execution checks out the same set.
struct SigScratch {
    arena: Arena,
    z: Vec<f64>,
    s: Vec<f64>,
    sig: Vec<f64>,
    lx: Vec<f64>,
    lacc: Vec<f64>,
    lnext: Vec<f64>,
}

impl SigScratch {
    fn checkout(arena: &Arena, od: usize, scratch_len: usize, log_total: usize) -> SigScratch {
        SigScratch {
            arena: arena.clone(),
            z: arena.take(od),
            s: arena.take(scratch_len),
            sig: arena.take(log_total),
            lx: arena.take(log_total),
            lacc: arena.take(log_total),
            lnext: arena.take(log_total),
        }
    }
}

impl Drop for SigScratch {
    fn drop(&mut self) {
        for b in [
            std::mem::take(&mut self.z),
            std::mem::take(&mut self.s),
            std::mem::take(&mut self.sig),
            std::mem::take(&mut self.lx),
            std::mem::take(&mut self.lacc),
            std::mem::take(&mut self.lnext),
        ] {
            self.arena.give(b);
        }
    }
}

/// Per-worker scratch for kernel plans.
struct KernScratch {
    arena: Arena,
    dx: Vec<f64>,
    dy: Vec<f64>,
    base: Vec<f64>,
    delta: Vec<f64>,
    prev: Vec<f64>,
    cur: Vec<f64>,
}

impl KernScratch {
    fn checkout(
        arena: &Arena,
        max_lx: usize,
        max_ly: usize,
        dim: usize,
        needs_base: bool,
        row_len: usize,
    ) -> KernScratch {
        let (mi, ni) = (max_lx.saturating_sub(1), max_ly.saturating_sub(1));
        KernScratch {
            arena: arena.clone(),
            dx: arena.take(mi * dim),
            dy: arena.take(ni * dim),
            base: arena.take(if needs_base { mi * ni } else { 0 }),
            delta: Vec::new(),
            prev: arena.take(row_len),
            cur: arena.take(row_len),
        }
    }
}

impl Drop for KernScratch {
    fn drop(&mut self) {
        for b in [
            std::mem::take(&mut self.dx),
            std::mem::take(&mut self.dy),
            std::mem::take(&mut self.base),
            std::mem::take(&mut self.delta),
            std::mem::take(&mut self.prev),
            std::mem::take(&mut self.cur),
        ] {
            self.arena.give(b);
        }
    }
}

/// Per-worker scratch for the lane-batched Gram producers: a
/// [`LaneScratch`] whose buffers are checked out of the plan's arena at
/// worker start, sized for the batch's largest pair (so
/// [`LaneScratch::ensure`] never grows them and the steady state stays
/// allocation-free), and returned on drop.
struct GramScratch {
    arena: Arena,
    inner: LaneScratch,
}

impl GramScratch {
    #[allow(clippy::too_many_arguments)]
    fn checkout(
        arena: &Arena,
        max_lx: usize,
        max_ly: usize,
        dim: usize,
        tr: Transform,
        lam2: u32,
        width: usize,
        max_cols: usize,
    ) -> GramScratch {
        // The ONE sizing source shared with the dispatcher's per-row
        // `ensure`: sizes are monotone in the lengths, so taking them at
        // the batch maxima guarantees `ensure` never grows an arena buffer.
        let s = lanes::lane_sizes(max_lx, max_ly, dim, tr, width, lam2);
        GramScratch {
            arena: arena.clone(),
            inner: LaneScratch {
                dx: arena.take(s.dx),
                dys: arena.take(s.dys),
                base: arena.take(s.base),
                delta: arena.take(s.delta),
                prev: arena.take(s.row),
                cur: arena.take(s.row),
                idx: arena.take_usize(max_cols),
            },
        }
    }
}

impl Drop for GramScratch {
    fn drop(&mut self) {
        let inner = std::mem::take(&mut self.inner);
        for b in [inner.dx, inner.dys, inner.base, inner.delta, inner.prev, inner.cur] {
            self.arena.give(b);
        }
        self.arena.give_usize(inner.idx);
    }
}

/// Run `body(i, scratch)` for `i in 0..n` with one scratch value per worker.
/// The worker count is `min(num_threads(), n)` — deterministic for a given
/// item count, so the arena's steady state is stable.
fn run_items<S, M, B>(parallel: bool, n: usize, make: M, body: B)
where
    S: Send,
    M: Fn() -> S,
    B: Fn(usize, &mut S) + Sync,
{
    if n == 0 {
        return;
    }
    let nt = if parallel { num_threads().min(n) } else { 1 };
    if nt <= 1 {
        let mut s = make();
        for i in 0..n {
            body(i, &mut s);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    // Check out every worker's scratch BEFORE spawning any worker: a fast
    // worker finishing early would otherwise return its buffers in time for
    // a later make() to reuse them, making the cold-run checkout count (and
    // with it the zero-allocation steady-state invariant) timing-dependent.
    let scratches: Vec<S> = (0..nt).map(|_| make()).collect();
    std::thread::scope(|scope| {
        let (cursor, body) = (&cursor, &body);
        for mut s in scratches {
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                body(i, &mut s);
            });
        }
    });
}

/// Retained forward state for [`ExecutionRecord::vjp`].
enum RecordState {
    None,
    /// Per-pair Δ matrices and full PDE grids (the paper's Algorithm 4
    /// inputs), concatenated flat with offset tables.
    KernelPairs {
        deltas: Vec<f64>,
        delta_off: Vec<usize>,
        grids: Vec<f64>,
        grid_off: Vec<usize>,
        /// `[m_i, n_i]` per pair (transformed Δ dims; 0 for degenerate pairs).
        dims: Vec<usize>,
    },
    /// The three Gram matrices behind an MMD² value.
    Mmd2 {
        kxx: Vec<f64>,
        kxy: Vec<f64>,
        kyy: Vec<f64>,
    },
    /// A fitted ridge regressor.
    Krr(Box<KernelRidge>),
    /// The feature map and both `[batch, rank]` feature matrices behind a
    /// low-rank Gram / MMD² value — retained for downstream reuse and for
    /// the feature-space backward. The map is shared with the plan's warm
    /// cache (it is immutable once built).
    LowRank {
        map: Arc<FeatureMap>,
        phi_x: Vec<f64>,
        phi_y: Vec<f64>,
    },
    /// A fitted low-rank ridge regressor.
    KrrLowRank(Box<LowRankRidge>),
}

/// Gradients returned by [`ExecutionRecord::vjp`]: one buffer per input
/// batch, in each batch's own (possibly ragged) flat layout.
#[derive(Debug, Clone, PartialEq)]
pub enum Gradients {
    Single(Vec<f64>),
    Pair(Vec<f64>, Vec<f64>),
}

impl Gradients {
    /// The single gradient of a one-input op; errors for pair ops.
    pub fn into_single(self) -> Result<Vec<f64>, SigError> {
        match self {
            Gradients::Single(g) => Ok(g),
            Gradients::Pair(..) => Err(SigError::Invalid("vjp produced a pair of gradients")),
        }
    }

    /// The (x, y) gradients of a pair op; errors for single-input ops.
    pub fn into_pair(self) -> Result<(Vec<f64>, Vec<f64>), SigError> {
        match self {
            Gradients::Pair(gx, gy) => Ok((gx, gy)),
            Gradients::Single(_) => Err(SigError::Invalid("vjp produced a single gradient")),
        }
    }
}

/// The result of one plan execution: output values plus the retained forward
/// intermediates. Buffers return to the plan's arena when the record drops,
/// which is what makes repeat executions allocation-free.
pub struct ExecutionRecord {
    spec: OpSpec,
    dim: usize,
    slen: usize,
    retain: bool,
    /// The plan's resolved lane width — the backward pass runs the same
    /// schedule the forward was compiled with (pure schedule: gradients are
    /// bit-identical across widths, property-tested).
    lanes: usize,
    arena: Arena,
    values: Vec<f64>,
    x_data: Vec<f64>,
    x_lengths: Vec<usize>,
    y_data: Vec<f64>,
    y_lengths: Vec<usize>,
    state: RecordState,
}

impl ExecutionRecord {
    /// Flat output values: `[batch, sig_length]` rows for signature ops,
    /// `[batch]` kernels, `[bx, by]` Gram, a single MMD² value, or KRR dual
    /// coefficients.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Detach the output buffer (it no longer returns to the arena).
    pub fn into_values(mut self) -> Vec<f64> {
        std::mem::take(&mut self.values)
    }

    /// First output value — the natural accessor for scalar ops.
    pub fn value(&self) -> f64 {
        self.values.first().copied().unwrap_or(0.0)
    }

    /// The retained Gram matrices (Kxx, Kxy, Kyy) of an MMD² execution.
    pub fn mmd_grams(&self) -> Option<(&[f64], &[f64], &[f64])> {
        match &self.state {
            RecordState::Mmd2 { kxx, kxy, kyy } => Some((kxx, kxy, kyy)),
            _ => None,
        }
    }

    /// Extract the fitted regressor of a KRR execution.
    pub fn into_kernel_ridge(mut self) -> Result<KernelRidge, SigError> {
        match std::mem::replace(&mut self.state, RecordState::None) {
            RecordState::Krr(model) => Ok(*model),
            other => {
                self.state = other;
                Err(SigError::Invalid("record does not hold a KRR fit"))
            }
        }
    }

    /// Extract the fitted regressor of a low-rank KRR execution.
    pub fn into_lowrank_ridge(mut self) -> Result<LowRankRidge, SigError> {
        match std::mem::replace(&mut self.state, RecordState::None) {
            RecordState::KrrLowRank(model) => Ok(*model),
            other => {
                self.state = other;
                Err(SigError::Invalid("record does not hold a low-rank KRR fit"))
            }
        }
    }

    /// The retained `[batch, rank]` feature matrices (Φx, Φy) of a low-rank
    /// Gram / MMD² execution, for downstream reuse (e.g. feeding a ridge
    /// solve without recomputing features).
    pub fn lowrank_features(&self) -> Option<(&[f64], &[f64], usize)> {
        match &self.state {
            RecordState::LowRank { map, phi_x, phi_y } => Some((phi_x, phi_y, map.rank())),
            _ => None,
        }
    }

    fn x_batch(&self) -> PathBatch<'_> {
        PathBatch::ragged(&self.x_data, &self.x_lengths, self.dim)
            .expect("internal: stored input batch is valid")
    }

    fn y_batch(&self) -> PathBatch<'_> {
        PathBatch::ragged(&self.y_data, &self.y_lengths, self.dim)
            .expect("internal: stored input batch is valid")
    }

    /// Exact vector–Jacobian product behind one API for the whole family.
    ///
    /// `Sig` records feed their forward rows into the time-reversed
    /// deconstruction (paper §2.4) and `SigKernel` records feed their
    /// retained Δ + PDE grids into Algorithm 4 (§3.4) — neither re-runs the
    /// forward sweep (a kernel-record vjp solves **zero** forward grid
    /// cells, asserted against [`pde_cells_solved`]). `Gram` and `Mmd2`
    /// route through the same lane-scheduled weighted-Gram backward as
    /// [`try_gram_vjp`](crate::kernel::try_gram_vjp) at the plan's compiled
    /// lane width, which re-derives each pair's grid (retaining O(b²) grids
    /// would dwarf the forward's memory); their retained Gram matrices are
    /// exposed via [`mmd_grams`](ExecutionRecord::mmd_grams) instead. When
    /// the two dyadic orders agree, the MMD² variants compute the Kxx term's
    /// two argument slots from one solve per unordered pair (the symmetric
    /// 2·∇₁ shortcut, ~half the solves). All gradients are bit-for-bit
    /// identical to the pre-existing typed `sig::backward` /
    /// `kernel::backward` entry points evaluated with the same options
    /// (including the forward `SigMethod`); lane width is pure schedule and
    /// never changes a bit of the result.
    ///
    /// [`pde_cells_solved`]: crate::kernel::pde_cells_solved
    ///
    /// The cotangent length matches the op's output: `[batch, sig_length]`
    /// (signatures), `[batch]` (paired kernels), `[bx, by]` (Gram), `[1]`
    /// (MMD²).
    pub fn vjp(&self, cotangent: &[f64]) -> Result<Gradients, SigError> {
        if !self.retain {
            return Err(SigError::Invalid(
                "plan was compiled forward-only; compile with retention for vjp",
            ));
        }
        match self.spec {
            OpSpec::Sig(o) => self.vjp_sig(&o, cotangent),
            OpSpec::LogSig(_) => Err(SigError::Invalid("log-signature vjp is not supported")),
            OpSpec::SigKernel(k) => self.vjp_kernel(&k, cotangent),
            OpSpec::Gram(k) => self.vjp_gram(&k, cotangent),
            OpSpec::Mmd2(k) => self.vjp_mmd2(&k, cotangent),
            OpSpec::Mmd2Unbiased(k) => self.vjp_mmd2_unbiased(&k, cotangent),
            OpSpec::GramLowRank { .. } => self.vjp_gram_lowrank(cotangent),
            OpSpec::Mmd2LowRank { .. } => self.vjp_mmd2_lowrank(cotangent),
            OpSpec::Krr { .. } | OpSpec::KrrLowRank { .. } => {
                Err(SigError::Invalid("vjp is not defined for KRR fits"))
            }
            OpSpec::GramCorpus { .. } | OpSpec::Mmd2Corpus { .. } | OpSpec::Mmd2Window { .. } => {
                Err(SigError::Invalid(
                    "corpus plans are forward-only; use Gram / Mmd2 plans for gradients",
                ))
            }
        }
    }

    fn vjp_sig(&self, o: &SigOptions, cotangent: &[f64]) -> Result<Gradients, SigError> {
        let b = self.x_lengths.len();
        let expected = b * self.slen;
        if cotangent.len() != expected {
            return Err(SigError::CotangentLen {
                expected,
                got: cotangent.len(),
            });
        }
        let xb = self.x_batch();
        let bounds = xb.element_offsets();
        let mut gx = vec![0.0; xb.total_points() * self.dim];
        let slen = self.slen;
        let work = |i: usize, row: &mut [f64]| {
            let p = xb.path(i);
            // The forward rows are the signatures — no forward re-run.
            let g = crate::sig::backward::signature_vjp_with_sig(
                p.data(),
                p.len(),
                p.dim(),
                o.depth,
                o.exec.transform,
                &self.values[i * slen..(i + 1) * slen],
                &cotangent[i * slen..(i + 1) * slen],
            );
            row.copy_from_slice(&g);
        };
        if o.exec.parallel {
            crate::util::pool::parallel_for_mut_ragged(&mut gx, &bounds, work);
        } else {
            for i in 0..b {
                let (lo, hi) = (bounds[i], bounds[i + 1]);
                work(i, &mut gx[lo..hi]);
            }
        }
        Ok(Gradients::Single(gx))
    }

    fn vjp_kernel(&self, k: &KernelOptions, cotangent: &[f64]) -> Result<Gradients, SigError> {
        let b = self.x_lengths.len();
        if cotangent.len() != b {
            return Err(SigError::CotangentLen {
                expected: b,
                got: cotangent.len(),
            });
        }
        // Re-resolve an ε-adaptive request from the same inputs the forward
        // saw — resolution is deterministic, so this lands on exactly the
        // (scheme, λ) the retained grids were solved at.
        let resolved = resolve_target_eps(&self.x_batch(), &self.y_batch(), k)?;
        let k = &resolved;
        let order2 = k.scheme == Scheme::Order2 && !order2_degenerate(k.dyadic_x, k.dyadic_y);
        let RecordState::KernelPairs {
            deltas,
            delta_off,
            grids,
            grid_off,
            dims,
        } = &self.state
        else {
            return Err(SigError::Invalid("record retains no kernel intermediates"));
        };
        let xb = self.x_batch();
        let yb = self.y_batch();
        let dim = self.dim;
        let xo = xb.element_offsets();
        let yo = yb.element_offsets();
        let mut gx = vec![0.0; xb.total_points() * dim];
        let mut gy = vec![0.0; yb.total_points() * dim];
        // Pair i exclusively owns gx row i AND gy row i (offsets are
        // non-decreasing, so the rows are disjoint) — both are written
        // through base pointers by the worker that owns `i ≡ t (mod nt)`.
        // No lock, hence no poisoning to unwrap. Per-pair heap traffic is
        // hoisted into per-worker scratch that grows to the batch maxima
        // once and is reused across the worker's rows.
        let nt = if k.exec.parallel { num_threads().min(b) } else { 1.min(b) };
        let gx_base = gx.as_mut_ptr() as usize;
        let gy_base = gy.as_mut_ptr() as usize;
        std::thread::scope(|s| {
            let (xo, yo) = (&xo, &yo);
            let (xb, yb) = (&xb, &yb);
            for t in 0..nt {
                s.spawn(move || {
                    let mut d1a: Vec<f64> = Vec::new();
                    let mut d1b: Vec<f64> = Vec::new();
                    let mut d2: Vec<f64> = Vec::new();
                    let mut dsc = crate::kernel::delta::DeltaVjpScratch::new();
                    let mut i = t;
                    while i < b {
                        let (lx, ly) = (self.x_lengths[i], self.y_lengths[i]);
                        let (m, n) = (dims[2 * i], dims[2 * i + 1]);
                        if m == 0 || n == 0 {
                            i += nt;
                            continue; // degenerate pair: kernel constant, zero gradient
                        }
                        // SAFETY: rows i ≡ t (mod nt) of gx and gy are
                        // written by exactly this worker; both buffers
                        // outlive the scope.
                        let gxrow = unsafe {
                            std::slice::from_raw_parts_mut(
                                (gx_base as *mut f64).add(xo[i]),
                                xo[i + 1] - xo[i],
                            )
                        };
                        let gyrow = unsafe {
                            std::slice::from_raw_parts_mut(
                                (gy_base as *mut f64).add(yo[i]),
                                yo[i + 1] - yo[i],
                            )
                        };
                        let delta = &deltas[delta_off[i]..delta_off[i + 1]];
                        let grid = &grids[grid_off[i]..grid_off[i + 1]];
                        // Algorithm 4 straight from the retained forward
                        // state: the adjoint sweep reads the stored grid(s),
                        // so zero forward cells are re-solved here. Under
                        // non-degenerate Order2 the retained region holds
                        // fine grid then coarse grid, concatenated.
                        let gf = if order2 {
                            ((m << k.dyadic_x) + 1) * ((n << k.dyadic_y) + 1)
                        } else {
                            grid.len()
                        };
                        let (gfine, gcoarse) = grid.split_at(gf);
                        if d2.len() < m * n {
                            d2.resize(m * n, 0.0);
                        }
                        crate::kernel::backward::sig_kernel_vjp_delta_scheme_into(
                            delta,
                            m,
                            n,
                            k.dyadic_x,
                            k.dyadic_y,
                            k.scheme,
                            gfine,
                            if order2 { Some(gcoarse) } else { None },
                            cotangent[i],
                            &mut d1a,
                            &mut d1b,
                            &mut d2[..m * n],
                        );
                        dsc.ensure(lx, ly, dim);
                        crate::kernel::delta::delta_vjp_to_paths_with(
                            &d2[..m * n],
                            xb.values_of(i),
                            yb.values_of(i),
                            lx,
                            ly,
                            dim,
                            k.exec.transform,
                            &mut dsc,
                            gxrow,
                            gyrow,
                        );
                        i += nt;
                    }
                });
            }
        });
        Ok(Gradients::Pair(gx, gy))
    }

    fn vjp_gram(&self, k: &KernelOptions, cotangent: &[f64]) -> Result<Gradients, SigError> {
        // Same lane schedule the plan compiled for the forward; width is
        // pure schedule, so this only moves occupancy, never a bit.
        let (gx, gy) = crate::kernel::try_gram_vjp_with_lanes(
            &self.x_batch(),
            &self.y_batch(),
            cotangent,
            k,
            self.lanes,
        )?;
        Ok(Gradients::Pair(gx, gy))
    }

    fn vjp_mmd2(&self, k: &KernelOptions, cotangent: &[f64]) -> Result<Gradients, SigError> {
        if cotangent.len() != 1 {
            return Err(SigError::CotangentLen {
                expected: 1,
                got: cotangent.len(),
            });
        }
        let c = cotangent[0];
        let (bx, by) = (self.x_lengths.len(), self.y_lengths.len());
        let xb = self.x_batch();
        let yb = self.y_batch();
        // The forward resolved ε against (x, y) once for all three Grams;
        // resolve the same way here (inner re-resolution is then a no-op).
        let resolved = resolve_target_eps(&xb, &yb, k)?;
        let k = &resolved;
        // ∂/∂x_i [ (1/bx²)ΣΣ k(x_a,x_b) ] needs BOTH argument slots of the
        // Kxx term: (1/bx²)[Σ_b ∇₁k(x_i,x_b) + Σ_a ∇₂k(x_a,x_i)]. When the
        // dyadic orders agree the discretised kernel is symmetric in its
        // arguments and the weights are constant, so one solve per unordered
        // pair yields both slots (the symmetric 2·∇₁ shortcut — the slots
        // stay separate to preserve this sum's association). With λ1 ≠ λ2
        // the discretised k(u,v) ≠ k(v,u) and both orientations must be
        // solved explicitly.
        let wxx = vec![c * (1.0 / (bx * bx) as f64); bx * bx];
        let (gxx1, gxx2) = if k.dyadic_x == k.dyadic_y {
            crate::kernel::gram_vjp_sym_with_lanes(&xb, &wxx, k, self.lanes)?
        } else {
            crate::kernel::try_gram_vjp_with_lanes(&xb, &xb, &wxx, k, self.lanes)?
        };
        let wxy = vec![c * (-2.0 / (bx * by) as f64); bx * by];
        let (gxy, _) = crate::kernel::try_gram_vjp_with_lanes(&xb, &yb, &wxy, k, self.lanes)?;
        Ok(Gradients::Single(
            gxx1.iter()
                .zip(gxx2.iter())
                .zip(gxy.iter())
                .map(|((a, b), g)| a + b + g)
                .collect(),
        ))
    }

    /// Same structure as [`vjp_mmd2`](Self::vjp_mmd2), but with the
    /// U-statistic's weights: the Kxx term puts 1/(bx(bx−1)) on every
    /// off-diagonal pair and **zero** on the diagonal (`try_gram_vjp` skips
    /// zero weights, so the diagonal solves are never run).
    fn vjp_mmd2_unbiased(
        &self,
        k: &KernelOptions,
        cotangent: &[f64],
    ) -> Result<Gradients, SigError> {
        if cotangent.len() != 1 {
            return Err(SigError::CotangentLen {
                expected: 1,
                got: cotangent.len(),
            });
        }
        let c = cotangent[0];
        let (bx, by) = (self.x_lengths.len(), self.y_lengths.len());
        let xb = self.x_batch();
        let yb = self.y_batch();
        // Same (x, y) resolution as the forward — see `vjp_mmd2`.
        let resolved = resolve_target_eps(&xb, &yb, k)?;
        let k = &resolved;
        let wo = c / (bx * (bx - 1)) as f64;
        let mut wxx = vec![wo; bx * bx];
        for i in 0..bx {
            wxx[i * bx + i] = 0.0;
        }
        // The U-statistic weight matrix is symmetric (constant off-diagonal,
        // zero diagonal), so matched dyadic orders take the same one-solve-
        // per-unordered-pair shortcut as the biased case; λ1 ≠ λ2 solves
        // both orientations (the discretised kernel is not symmetric in its
        // arguments then).
        let (gxx1, gxx2) = if k.dyadic_x == k.dyadic_y {
            crate::kernel::gram_vjp_sym_with_lanes(&xb, &wxx, k, self.lanes)?
        } else {
            crate::kernel::try_gram_vjp_with_lanes(&xb, &xb, &wxx, k, self.lanes)?
        };
        let wxy = vec![c * (-2.0 / (bx * by) as f64); bx * by];
        let (gxy, _) = crate::kernel::try_gram_vjp_with_lanes(&xb, &yb, &wxy, k, self.lanes)?;
        Ok(Gradients::Single(
            gxx1.iter()
                .zip(gxx2.iter())
                .zip(gxy.iter())
                .map(|((a, b), g)| a + b + g)
                .collect(),
        ))
    }

    /// Low-rank Gram backward: with G = Φx·Φyᵀ and the feature map frozen
    /// (Nyström landmark selection is not differentiated), ∂F/∂Φx = W·Φy
    /// and ∂F/∂Φy = Wᵀ·Φx; the retained feature matrices supply both, and
    /// the map's backward routes them to path space through the exact
    /// kernel / signature vjp machinery.
    fn vjp_gram_lowrank(&self, cotangent: &[f64]) -> Result<Gradients, SigError> {
        let RecordState::LowRank { map, phi_x, phi_y } = &self.state else {
            return Err(SigError::Invalid("record retains no low-rank features"));
        };
        let (bx, by) = (self.x_lengths.len(), self.y_lengths.len());
        if cotangent.len() != bx * by {
            return Err(SigError::CotangentLen {
                expected: bx * by,
                got: cotangent.len(),
            });
        }
        let r = map.rank();
        let mut gpx = vec![0.0; bx * r];
        crate::util::linalg::gemm(bx, by, r, cotangent, phi_y, &mut gpx);
        let mut gpy = vec![0.0; by * r];
        for i in 0..bx {
            let prow = &phi_x[i * r..(i + 1) * r];
            for j in 0..by {
                let w = cotangent[i * by + j];
                if w == 0.0 {
                    continue;
                }
                for (o, &p) in gpy[j * r..(j + 1) * r].iter_mut().zip(prow.iter()) {
                    *o += w * p;
                }
            }
        }
        let gx = map.try_features_vjp(&self.x_batch(), &gpx)?;
        let gy = map.try_features_vjp(&self.y_batch(), &gpy)?;
        Ok(Gradients::Pair(gx, gy))
    }

    /// Low-rank MMD² backward: ∂F/∂φ(x_i) = c·(2/bx)(mean Φx − mean Φy) for
    /// every row, from the retained feature matrices. The gradient is with
    /// respect to the x-paths only (matching [`OpSpec::Mmd2`]); landmarks
    /// come from y, so no frozen-landmark approximation enters the x-side.
    fn vjp_mmd2_lowrank(&self, cotangent: &[f64]) -> Result<Gradients, SigError> {
        if cotangent.len() != 1 {
            return Err(SigError::CotangentLen {
                expected: 1,
                got: cotangent.len(),
            });
        }
        let RecordState::LowRank { map, phi_x, phi_y } = &self.state else {
            return Err(SigError::Invalid("record retains no low-rank features"));
        };
        let c = cotangent[0];
        let (bx, by) = (self.x_lengths.len(), self.y_lengths.len());
        let r = map.rank();
        let mx = crate::kernel::lowrank::feature_mean(phi_x, bx, r);
        let my = crate::kernel::lowrank::feature_mean(phi_y, by, r);
        let scale = c * 2.0 / bx as f64;
        let row: Vec<f64> = mx
            .iter()
            .zip(my.iter())
            .map(|(a, b)| scale * (a - b))
            .collect();
        let mut grad_phi = vec![0.0; bx * r];
        for chunk in grad_phi.chunks_mut(r) {
            chunk.copy_from_slice(&row);
        }
        map.try_features_vjp(&self.x_batch(), &grad_phi)
            .map(Gradients::Single)
    }
}

impl Drop for ExecutionRecord {
    fn drop(&mut self) {
        let arena = self.arena.clone();
        arena.give(std::mem::take(&mut self.values));
        arena.give(std::mem::take(&mut self.x_data));
        arena.give(std::mem::take(&mut self.y_data));
        arena.give_usize(std::mem::take(&mut self.x_lengths));
        arena.give_usize(std::mem::take(&mut self.y_lengths));
        match std::mem::replace(&mut self.state, RecordState::None) {
            RecordState::KernelPairs {
                deltas,
                delta_off,
                grids,
                grid_off,
                dims,
            } => {
                arena.give(deltas);
                arena.give(grids);
                arena.give_usize(delta_off);
                arena.give_usize(grid_off);
                arena.give_usize(dims);
            }
            RecordState::Mmd2 { kxx, kxy, kyy } => {
                arena.give(kxx);
                arena.give(kxy);
                arena.give(kyy);
            }
            RecordState::LowRank { phi_x, phi_y, .. } => {
                arena.give(phi_x);
                arena.give(phi_y);
            }
            RecordState::None | RecordState::Krr(_) | RecordState::KrrLowRank(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sig_plan_reuse_allocates_nothing_on_second_run() {
        let mut rng = Rng::new(11);
        let (b, l, d) = (6, 12, 2);
        let data = rng.brownian_batch(b, l, d, 0.4);
        let pb = PathBatch::uniform(&data, b, l, d).unwrap();
        for opts in [SigOptions::new(3), SigOptions::new(3).serial()] {
            let plan = Plan::compile(OpSpec::Sig(opts), ShapeClass::uniform(d, l)).unwrap();
            let r1 = plan.execute(&pb).unwrap();
            let first = r1.values().to_vec();
            drop(r1);
            let warm = plan.allocations();
            assert!(warm > 0);
            let r2 = plan.execute(&pb).unwrap();
            assert_eq!(r2.values(), &first[..], "plan reuse must be bit-identical");
            drop(r2);
            assert_eq!(
                plan.allocations(),
                warm,
                "second run must not allocate (parallel={})",
                opts.exec.parallel
            );
        }
    }

    #[test]
    fn kernel_plan_reuse_allocates_nothing_on_second_run() {
        let mut rng = Rng::new(12);
        let (b, l, d) = (4, 8, 2);
        let x = rng.brownian_batch(b, l, d, 0.4);
        let y = rng.brownian_batch(b, l, d, 0.4);
        let xb = PathBatch::uniform(&x, b, l, d).unwrap();
        let yb = PathBatch::uniform(&y, b, l, d).unwrap();
        let opts = KernelOptions::default().dyadic(1, 0);
        // Both the forward-only and the record-keeping (grid-retaining)
        // plans must reach a zero-allocation steady state.
        for retain in [false, true] {
            let plan = Plan::compile_custom(
                OpSpec::SigKernel(opts),
                ShapeClass::uniform(d, l),
                retain,
                None,
            )
            .unwrap();
            let r1 = plan.execute_pair(&xb, &yb).unwrap();
            let first = r1.values().to_vec();
            drop(r1);
            let warm = plan.allocations();
            let r2 = plan.execute_pair(&xb, &yb).unwrap();
            assert_eq!(r2.values(), &first[..]);
            drop(r2);
            assert_eq!(plan.allocations(), warm, "retain={retain}");
        }
    }

    #[test]
    fn plan_rejects_wrong_shape_class() {
        let plan = Plan::compile(OpSpec::Sig(SigOptions::new(2)), ShapeClass::uniform(2, 8))
            .unwrap();
        let data = vec![0.0; 2 * 6 * 2];
        let pb = PathBatch::uniform(&data, 2, 6, 2).unwrap();
        assert!(matches!(plan.execute(&pb), Err(SigError::Invalid(_))));
        let d3 = vec![0.0; 8 * 3];
        let pb3 = PathBatch::uniform(&d3, 1, 8, 3).unwrap();
        assert!(matches!(
            plan.execute(&pb3),
            Err(SigError::DimMismatch { .. })
        ));
        // Wrong arity.
        assert!(matches!(
            plan.execute_pair(&pb, &pb),
            Err(SigError::Invalid(_))
        ));
    }

    #[test]
    fn compile_rejects_hostile_specs() {
        assert!(matches!(
            Plan::compile(OpSpec::Sig(SigOptions::new(0)), ShapeClass::uniform(2, 8)),
            Err(SigError::ZeroDepth)
        ));
        assert!(matches!(
            Plan::compile(OpSpec::Sig(SigOptions::new(64)), ShapeClass::uniform(2, 8)),
            Err(SigError::TooLarge(_))
        ));
        assert!(matches!(
            Plan::compile(
                OpSpec::SigKernel(KernelOptions::default().dyadic(60, 0)),
                ShapeClass::ragged(2, 16)
            ),
            Err(SigError::TooLarge(_))
        ));
        assert!(matches!(
            Plan::compile(OpSpec::Sig(SigOptions::new(2)), ShapeClass::uniform(0, 8)),
            Err(SigError::ZeroDim)
        ));
    }

    #[test]
    fn forward_only_records_refuse_vjp() {
        let mut rng = Rng::new(13);
        let data = rng.brownian_batch(2, 6, 2, 0.4);
        let pb = PathBatch::uniform(&data, 2, 6, 2).unwrap();
        let plan =
            Plan::compile_forward(OpSpec::Sig(SigOptions::new(2)), ShapeClass::uniform(2, 6))
                .unwrap();
        let rec = plan.execute(&pb).unwrap();
        let cot = vec![0.0; rec.values().len()];
        assert!(matches!(rec.vjp(&cot), Err(SigError::Invalid(_))));
    }

    #[test]
    fn ragged_class_executes_mixed_lengths() {
        let mut rng = Rng::new(14);
        let d = 2;
        let lengths = [5usize, 1, 9];
        let mut data = Vec::new();
        for &l in &lengths {
            data.extend(rng.brownian_path(l, d, 0.4));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        let plan = Plan::compile(
            OpSpec::Sig(SigOptions::new(3)),
            ShapeClass::ragged(d, 9),
        )
        .unwrap();
        let rec = plan.execute(&pb).unwrap();
        let slen = crate::sig::sig_length(d, 3);
        let mut off = 0;
        for (i, &l) in lengths.iter().enumerate() {
            let want = crate::sig::sig(&data[off * d..(off + l) * d], l, d, 3);
            assert_eq!(&rec.values()[i * slen..(i + 1) * slen], &want[..]);
            off += l;
        }
        // A longer path than the class allows is rejected.
        let long = rng.brownian_path(12, d, 0.4);
        let lb = PathBatch::uniform(&long, 1, 12, d).unwrap();
        assert!(matches!(plan.execute(&lb), Err(SigError::Invalid(_))));
    }

    #[test]
    fn krr_plan_fits_and_returns_model() {
        let mut rng = Rng::new(15);
        let (n, l, d) = (8, 6, 2);
        let data = rng.brownian_batch(n, l, d, 0.3);
        let pb = PathBatch::uniform(&data, n, l, d).unwrap();
        let y: Vec<f64> = (0..n).map(|i| i as f64 * 0.1).collect();
        let plan = Plan::compile(
            OpSpec::Krr {
                opts: KernelOptions::default(),
                lambda: 1e-3,
                normalize: true,
            },
            ShapeClass::uniform(d, l),
        )
        .unwrap();
        let rec = plan.execute_fit(&pb, &y).unwrap();
        assert_eq!(rec.values().len(), n);
        let model = rec.into_kernel_ridge().unwrap();
        let pred = model.try_predict(&pb).unwrap();
        assert_eq!(pred.len(), n);
    }
}
