//! Lane-engine bit-identity properties (the tentpole acceptance surface):
//! lane-batched Gram / MMD² / corpus results must equal the scalar path
//! **bit for bit** — for every lane width, over uniform and ragged batches,
//! with and without the plan cache. Lane batching is pure schedule: each
//! lane of a group runs the scalar solver's FP sequence on the scalar Δ
//! values, so any difference at all is a bug.

use pysiglib::corpus::{CorpusRegistry, TileScheduler};
use pysiglib::engine::{OpSpec, Plan, Session, ShapeClass};
use pysiglib::kernel::{try_gram, try_mmd2, KernelOptions, SolverKind};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

/// Ragged lengths with enough repeats that W = 8 groups actually form.
const RAGGED_X: [usize; 10] = [6, 9, 6, 6, 9, 6, 6, 6, 1, 6];
const RAGGED_Y: [usize; 13] = [5, 5, 8, 5, 5, 5, 8, 5, 5, 5, 5, 1, 5];

fn ragged(rng: &mut Rng, lens: &[usize], d: usize) -> (Vec<f64>, Vec<usize>) {
    let mut data = Vec::new();
    for &l in lens {
        data.extend(rng.brownian_path(l, d, 0.4));
    }
    (data, lens.to_vec())
}

fn opts_matrix() -> Vec<KernelOptions> {
    vec![
        KernelOptions::default(),
        KernelOptions::default().dyadic(1, 2),
        KernelOptions::default().dyadic(2, 0),
        KernelOptions::default().transform(Transform::TimeAug),
        KernelOptions::default().transform(Transform::LeadLag),
        KernelOptions::default().serial(),
    ]
}

/// Gram plans: widths 4 and 8 must reproduce the scalar plan bitwise, on
/// uniform and ragged pairs, across options.
#[test]
fn gram_plans_bitmatch_scalar_for_every_width() {
    let mut rng = Rng::new(920);
    let d = 2;
    let xu = rng.brownian_batch(13, 7, d, 0.4);
    let yu = rng.brownian_batch(11, 6, d, 0.4);
    let (xr_data, xr_lens) = ragged(&mut rng, &RAGGED_X, d);
    let (yr_data, yr_lens) = ragged(&mut rng, &RAGGED_Y, d);
    let xub = PathBatch::uniform(&xu, 13, 7, d).unwrap();
    let yub = PathBatch::uniform(&yu, 11, 6, d).unwrap();
    let xrb = PathBatch::ragged(&xr_data, &xr_lens, d).unwrap();
    let yrb = PathBatch::ragged(&yr_data, &yr_lens, d).unwrap();
    for (xb, yb, tag) in [(&xub, &yub, "uniform"), (&xrb, &yrb, "ragged")] {
        for opts in opts_matrix() {
            let shape = ShapeClass::for_pair(xb, yb);
            let scalar = Plan::compile_forward(OpSpec::Gram(opts), shape)
                .unwrap()
                .with_lane_width(0);
            let want = scalar.execute_pair(xb, yb).unwrap().into_values();
            for width in [4usize, 8] {
                let plan = Plan::compile_forward(OpSpec::Gram(opts), shape)
                    .unwrap()
                    .with_lane_width(width);
                assert_eq!(plan.lane_width(), width);
                let got = plan.execute_pair(xb, yb).unwrap().into_values();
                assert_eq!(got, want, "{tag} width={width} opts={opts:?}");
            }
        }
    }
}

/// MMD² (biased + unbiased) through lane-batched Gram producers must be
/// bit-identical to the scalar plans, and the blocked solver must keep its
/// scalar schedule regardless of the requested width.
#[test]
fn mmd2_plans_bitmatch_scalar_for_every_width() {
    let mut rng = Rng::new(921);
    let d = 3;
    let x = rng.brownian_batch(10, 6, d, 0.4);
    let y = rng.brownian_batch(9, 6, d, 0.5);
    let xb = PathBatch::uniform(&x, 10, 6, d).unwrap();
    let yb = PathBatch::uniform(&y, 9, 6, d).unwrap();
    let shape = ShapeClass::for_pair(&xb, &yb);
    for spec in [
        OpSpec::Mmd2(KernelOptions::default()),
        OpSpec::Mmd2Unbiased(KernelOptions::default()),
        OpSpec::Mmd2(KernelOptions::default().dyadic(1, 1)),
        OpSpec::Mmd2(KernelOptions::default().solver(SolverKind::Blocked)),
    ] {
        let scalar = Plan::compile_forward(spec, shape).unwrap().with_lane_width(0);
        let want = scalar.execute_pair(&xb, &yb).unwrap().value();
        for width in [4usize, 8] {
            let plan = Plan::compile_forward(spec, shape).unwrap().with_lane_width(width);
            let got = plan.execute_pair(&xb, &yb).unwrap().value();
            assert_eq!(got, want, "spec={} width={width}", spec.name());
        }
    }
}

/// The plan cache serves the lane-batched fast path: cached (warm) plans,
/// one-shot plans and the scalar schedule all agree bitwise, and the warm
/// execution really is a cache hit.
#[test]
fn plan_cache_serves_lane_batched_values() {
    let mut rng = Rng::new(922);
    let d = 2;
    let x = rng.brownian_batch(12, 8, d, 0.4);
    let y = rng.brownian_batch(12, 8, d, 0.4);
    let xb = PathBatch::uniform(&x, 12, 8, d).unwrap();
    let yb = PathBatch::uniform(&y, 12, 8, d).unwrap();
    let opts = KernelOptions::default();
    let shape = ShapeClass::for_pair(&xb, &yb);
    let session = Session::new();
    let plan = session.forward_plan(OpSpec::Gram(opts), shape).unwrap();
    let cold = plan.execute_pair(&xb, &yb).unwrap().into_values();
    let warm_plan = session.forward_plan(OpSpec::Gram(opts), shape).unwrap();
    let warm = warm_plan.execute_pair(&xb, &yb).unwrap().into_values();
    assert!(session.cache_stats().hits >= 1, "second lookup must hit");
    assert_eq!(cold, warm, "cached plan must reproduce its own values");
    let scalar = Plan::compile_forward(OpSpec::Gram(opts), shape)
        .unwrap()
        .with_lane_width(0);
    let want = scalar.execute_pair(&xb, &yb).unwrap().into_values();
    assert_eq!(cold, want, "plan-cache path must equal the scalar schedule");
    // The convenience wrapper (its own one-shot plan) agrees too.
    assert_eq!(try_gram(&xb, &yb, &opts).unwrap(), want);
}

/// Corpus registry: tiled + lane-batched self-Grams, cross-Grams and MMD²
/// queries are bit-identical across lane widths and tile sizes, uniform
/// and ragged, exact match against the direct estimators.
#[test]
fn corpus_queries_bitmatch_across_lane_widths() {
    let mut rng = Rng::new(923);
    let d = 2;
    let cu = rng.brownian_batch(12, 6, d, 0.3);
    let qu = rng.brownian_batch(5, 7, d, 0.35);
    let (cr_data, cr_lens) = ragged(&mut rng, &RAGGED_Y, d);
    let (qr_data, qr_lens) = ragged(&mut rng, &[4usize, 6, 4, 4], d);
    let cub = PathBatch::uniform(&cu, 12, 6, d).unwrap();
    let qub = PathBatch::uniform(&qu, 5, 7, d).unwrap();
    let crb = PathBatch::ragged(&cr_data, &cr_lens, d).unwrap();
    let qrb = PathBatch::ragged(&qr_data, &qr_lens, d).unwrap();
    let opts = KernelOptions::default();
    for (cb, qb, tag) in [(&cub, &qub, "uniform"), (&crb, &qrb, "ragged")] {
        let want_gram = try_gram(qb, cb, &opts).unwrap();
        let want_mmd = try_mmd2(qb, cb, &opts).unwrap();
        for tile in [3usize, 16] {
            for width in [0usize, 4, 8] {
                let reg = CorpusRegistry::with_tiles(
                    TileScheduler::with_tile(tile).with_lanes(width),
                );
                let id = reg.register(cb).unwrap();
                let gram = reg.gram_query(id, qb, &opts, None).unwrap();
                assert_eq!(gram, want_gram, "{tag} tile={tile} width={width}");
                let cold = reg.mmd2_query(id, qb, &opts, None).unwrap();
                let warm = reg.mmd2_query(id, qb, &opts, None).unwrap();
                assert_eq!(cold, want_mmd, "{tag} tile={tile} width={width}");
                assert_eq!(cold, warm, "warm re-query must be bit-identical");
            }
        }
    }
}

/// Append-then-query stays bit-identical to a from-scratch registration
/// when the incremental strips are lane-batched.
#[test]
fn lane_batched_append_matches_from_scratch() {
    let mut rng = Rng::new(924);
    let d = 2;
    let (l, n0, k) = (6usize, 9usize, 4usize);
    let part1 = rng.brownian_batch(n0, l, d, 0.3);
    let part2 = rng.brownian_batch(k, l, d, 0.3);
    let q = rng.brownian_batch(3, l, d, 0.4);
    let p1 = PathBatch::uniform(&part1, n0, l, d).unwrap();
    let p2 = PathBatch::uniform(&part2, k, l, d).unwrap();
    let qb = PathBatch::uniform(&q, 3, l, d).unwrap();
    let mut combined = part1.clone();
    combined.extend_from_slice(&part2);
    let cb = PathBatch::uniform(&combined, n0 + k, l, d).unwrap();
    let opts = KernelOptions::default();
    for width in [0usize, 4, 8] {
        let tiles = TileScheduler::with_tile(4).with_lanes(width);
        let reg = CorpusRegistry::with_tiles(tiles);
        let id = reg.register(&p1).unwrap();
        reg.mmd2_query(id, &qb, &opts, None).unwrap(); // warm the K_cc cache
        reg.append(id, &p2).unwrap();
        let appended = reg.mmd2_query(id, &qb, &opts, None).unwrap();
        let scratch = CorpusRegistry::with_tiles(tiles);
        let sid = scratch.register(&cb).unwrap();
        let fresh = scratch.mmd2_query(sid, &qb, &opts, None).unwrap();
        assert_eq!(appended, fresh, "width={width}");
    }
}
