//! Cross-module property tests: invariants that tie signatures, kernels,
//! transforms and gradients together.

use pysiglib::kernel::{mmd2, mmd2_with_grad, sig_kernel, try_gram, KernelOptions};
use pysiglib::sig::{sig, sig_length, try_batch_signature, SigOptions};
use pysiglib::tensor::inner_product;
use pysiglib::transforms::Transform;
use pysiglib::util::prop::check;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

/// The PDE kernel and the explicit truncated signature inner product agree
/// once the truncation is deep enough and the PDE grid fine enough.
#[test]
fn kernel_equals_signature_inner_product_in_the_limit() {
    check("kernel == <S,S> limit", 8, |g| {
        let lx = g.usize_in(2, 4);
        let ly = g.usize_in(2, 4);
        let d = g.usize_in(1, 3);
        let x = g.path(lx, d, 0.2);
        let y = g.path(ly, d, 0.2);
        let k = sig_kernel(&x, &y, lx, ly, d, &KernelOptions::default().dyadic(6, 6));
        let sx = sig(&x, lx, d, 12);
        let sy = sig(&y, ly, d, 12);
        let ip = inner_product(&sx, &sy);
        assert!(
            (k - ip).abs() < 3e-3 * ip.abs().max(1.0),
            "kernel {k} vs inner product {ip}"
        );
    });
}

/// Time-augmenting both paths changes the kernel exactly as materialising
/// the transform would (fused == materialised through the whole kernel).
#[test]
fn kernel_transform_consistency_via_signatures() {
    check("transformed kernel == transformed sig inner product", 5, |g| {
        let l = g.usize_in(2, 4);
        let d = g.usize_in(1, 2);
        let x = g.path(l, d, 0.15);
        let y = g.path(l, d, 0.15);
        let opts = KernelOptions::default()
            .dyadic(6, 6)
            .transform(Transform::TimeAug);
        let k = sig_kernel(&x, &y, l, l, d, &opts);
        let xm = pysiglib::transforms::time_augment(&x, l, d);
        let ym = pysiglib::transforms::time_augment(&y, l, d);
        let sx = sig(&xm, l, d + 1, 12);
        let sy = sig(&ym, l, d + 1, 12);
        let ip = inner_product(&sx, &sy);
        assert!(
            (k - ip).abs() < 5e-3 * ip.abs().max(1.0),
            "kernel {k} vs ip {ip}"
        );
    });
}

/// One gradient-descent step on MMD² must reduce the loss (for a small
/// enough step) — the end-to-end training-signal sanity check.
#[test]
fn mmd_gradient_descends() {
    let mut rng = Rng::new(400);
    let (bx, by, l, d) = (4, 4, 6, 2);
    let mut x = rng.brownian_batch(bx, l, d, 0.8);
    let y = rng.brownian_batch(by, l, d, 0.3);
    let opts = KernelOptions::default();
    let (before, grad) = mmd2_with_grad(&x, &y, bx, by, l, l, d, &opts);
    let gnorm = pysiglib::util::linalg::norm2(&grad);
    assert!(gnorm > 0.0);
    let step = 0.01 / gnorm.max(1.0);
    for (xi, gi) in x.iter_mut().zip(grad.iter()) {
        *xi -= step * gi;
    }
    let after = mmd2(&x, &y, bx, by, l, l, d, &opts);
    assert!(
        after < before,
        "MMD did not decrease: {before} -> {after}"
    );
}

/// Batched signatures of lead-lag paths have the dimension the transform
/// promises, and level-2 of the lead-lag signature encodes quadratic
/// variation on the anti-diagonal blocks (nonzero for rough paths).
#[test]
fn leadlag_signature_quadratic_variation_block() {
    let mut rng = Rng::new(401);
    let (l, d) = (64, 1);
    let path = rng.brownian_path(l, d, 0.5);
    let s = pysiglib::sig::signature(
        &path,
        l,
        d,
        2,
        Transform::LeadLag,
        pysiglib::sig::SigMethod::Horner,
    );
    assert_eq!(s.len(), sig_length(2, 2));
    // Lead-lag level 2: S^{(2)}[lead,lag] - S^{(2)}[lag,lead] ≈ QV (Lévy
    // area between lead and lag equals half the quadratic variation; the
    // antisymmetric part must be nonzero for a Brownian-like path).
    let o2 = 1 + 2; // offsets: level0 (1) + level1 (2)
    let area = s[o2 + 1] - s[o2 + 2]; // indices (0,1) and (1,0)
    let qv: f64 = (0..l - 1)
        .map(|i| (path[i + 1] - path[i]).powi(2))
        .sum();
    assert!(
        (area.abs() - qv).abs() < 0.5 * qv,
        "lead-lag area {area} vs QV {qv}"
    );
}

/// Serving options equivalence: serial and parallel batch APIs with every
/// transform produce identical results.
#[test]
fn batch_parallel_serial_equivalence_all_transforms() {
    check("batch parallel == serial (all transforms)", 6, |g| {
        let b = g.usize_in(1, 6);
        let l = g.usize_in(2, 10);
        let d = g.usize_in(1, 3);
        let mut paths = Vec::new();
        for _ in 0..b {
            paths.extend(g.path(l, d, 0.4));
        }
        for tr in [Transform::None, Transform::TimeAug, Transform::LeadLag] {
            let par = pysiglib::sig::batch_signature(
                &paths,
                b,
                l,
                d,
                &SigOptions::new(3).transform(tr),
            );
            let ser = pysiglib::sig::batch_signature(
                &paths,
                b,
                l,
                d,
                &SigOptions::new(3).transform(tr).serial(),
            );
            assert_eq!(par, ser);
        }
    });
}

/// The ragged-batch contract (acceptance criterion): `PathBatch::ragged`
/// batch-signature results exactly bit-match a per-path loop over `sig`,
/// across random shapes — including the empty batch and length-1 paths.
#[test]
fn ragged_batch_signature_bitmatches_per_path_loop() {
    check("ragged batch signature == per-path loop", 20, |g| {
        let b = g.usize_in(0, 6); // 0 ⇒ empty-batch case
        let d = g.usize_in(1, 3);
        let depth = g.usize_in(1, 4);
        let mut lengths = Vec::with_capacity(b);
        let mut data = Vec::new();
        for _ in 0..b {
            let l = g.usize_in(1, 12); // 1 ⇒ trivial-path case
            lengths.push(l);
            data.extend(g.path(l, d, 0.5));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        let out = try_batch_signature(&pb, &SigOptions::new(depth)).unwrap();
        let slen = sig_length(d, depth);
        assert_eq!(out.len(), b * slen);
        let mut off = 0;
        for (i, &l) in lengths.iter().enumerate() {
            let want = sig(&data[off * d..(off + l) * d], l, d, depth);
            assert_eq!(&out[i * slen..(i + 1) * slen], &want[..], "path {i}");
            off += l;
        }
    });
}

/// Same contract for the Gram matrix: every ragged pair bit-matches
/// `sig_kernel` on the pair's own lengths (length-1 paths give exactly 1).
#[test]
fn ragged_gram_bitmatches_per_pair_loop() {
    check("ragged gram == per-pair loop", 12, |g| {
        let bx = g.usize_in(0, 4);
        let by = g.usize_in(0, 4);
        let d = g.usize_in(1, 3);
        let mut build = |b: usize| {
            let mut lengths = Vec::with_capacity(b);
            let mut data = Vec::new();
            for _ in 0..b {
                let l = g.usize_in(1, 8);
                lengths.push(l);
                data.extend(g.path(l, d, 0.4));
            }
            (lengths, data)
        };
        let (xl, xdata) = build(bx);
        let (yl, ydata) = build(by);
        let xb = PathBatch::ragged(&xdata, &xl, d).unwrap();
        let yb = PathBatch::ragged(&ydata, &yl, d).unwrap();
        let opts = KernelOptions::default();
        let gm = try_gram(&xb, &yb, &opts).unwrap();
        assert_eq!(gm.len(), bx * by);
        let mut xo = 0;
        for (i, &lx) in xl.iter().enumerate() {
            let mut yo = 0;
            for (j, &ly) in yl.iter().enumerate() {
                let want = if lx < 2 || ly < 2 {
                    1.0
                } else {
                    sig_kernel(
                        &xdata[xo * d..(xo + lx) * d],
                        &ydata[yo * d..(yo + ly) * d],
                        lx,
                        ly,
                        d,
                        &opts,
                    )
                };
                assert_eq!(gm[i * by + j], want, "pair ({i},{j})");
                yo += ly;
            }
            xo += lx;
        }
    });
}

/// Scaling the path scales level k of the signature by λ^k (homogeneity).
#[test]
fn signature_homogeneity() {
    check("signature homogeneity", 10, |g| {
        let l = g.usize_in(2, 8);
        let d = g.usize_in(1, 3);
        let depth = g.usize_in(1, 4);
        let lam = g.f64_in(0.3, 2.0);
        let path = g.path(l, d, 0.5);
        let scaled: Vec<f64> = path.iter().map(|v| v * lam).collect();
        let s1 = sig(&path, l, d, depth);
        let s2 = sig(&scaled, l, d, depth);
        let layout = pysiglib::tensor::LevelLayout::new(d, depth);
        for k in 0..=depth {
            let (a, b) = layout.level_range(k);
            let f = lam.powi(k as i32);
            for i in a..b {
                assert!(
                    (s2[i] - f * s1[i]).abs() < 1e-9 * (1.0 + (f * s1[i]).abs()),
                    "level {k}"
                );
            }
        }
    });
}
