//! Backward-pass bit-identity properties (the PR's acceptance surface):
//! the lane-batched Algorithm-4 adjoint must equal the scalar backward
//! **bit for bit** — for every lane width, over uniform and ragged batches,
//! across transforms and dyadic orders, through both the typed
//! `try_gram_vjp_with_lanes` entry point and `record.vjp` on every kernel
//! record family. Lane batching is pure schedule: each lane replays the
//! scalar adjoint's FP sequence, so any difference at all is a bug. The
//! symmetric 2·∇₁ Kxx shortcut is additionally pinned exactly where its
//! algebra is exact (bx = 2, λ = 0) and to 1e-12 elsewhere.

use pysiglib::engine::{OpSpec, Plan, ShapeClass};
use pysiglib::kernel::{
    try_gram, try_gram_vjp, try_gram_vjp_with_lanes, try_sig_kernel_vjp, KernelOptions,
};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

/// Ragged lengths with enough repeats that W = 8 groups actually form.
const RAGGED_X: [usize; 10] = [6, 9, 6, 6, 9, 6, 6, 6, 1, 6];
const RAGGED_Y: [usize; 13] = [5, 5, 8, 5, 5, 5, 8, 5, 5, 5, 5, 1, 5];

fn ragged(rng: &mut Rng, lens: &[usize], d: usize) -> (Vec<f64>, Vec<usize>) {
    let mut data = Vec::new();
    for &l in lens {
        data.extend(rng.brownian_path(l, d, 0.4));
    }
    (data, lens.to_vec())
}

fn opts_matrix() -> Vec<KernelOptions> {
    vec![
        KernelOptions::default(),
        KernelOptions::default().dyadic(1, 2),
        KernelOptions::default().dyadic(2, 0),
        KernelOptions::default().transform(Transform::TimeAug),
        KernelOptions::default().transform(Transform::LeadLag),
        KernelOptions::default().serial(),
    ]
}

/// Weights with structural zeros: zero-weight columns must be *skipped*
/// identically by the scalar and lane schedules (they shape the groups).
fn weights(rng: &mut Rng, bx: usize, by: usize) -> Vec<f64> {
    let mut w = vec![0.0; bx * by];
    rng.fill_normal(&mut w);
    for (i, v) in w.iter_mut().enumerate() {
        if i % 5 == 3 {
            *v = 0.0;
        }
    }
    w
}

/// Tentpole property: the weighted-Gram backward is bit-identical across
/// lane widths 0 / 4 / 8, uniform and ragged, across the options matrix.
#[test]
fn gram_backward_bitmatches_scalar_for_every_width() {
    let mut rng = Rng::new(930);
    let d = 2;
    let xu = rng.brownian_batch(9, 7, d, 0.4);
    let yu = rng.brownian_batch(11, 6, d, 0.4);
    let (xr_data, xr_lens) = ragged(&mut rng, &RAGGED_X, d);
    let (yr_data, yr_lens) = ragged(&mut rng, &RAGGED_Y, d);
    let xub = PathBatch::uniform(&xu, 9, 7, d).unwrap();
    let yub = PathBatch::uniform(&yu, 11, 6, d).unwrap();
    let xrb = PathBatch::ragged(&xr_data, &xr_lens, d).unwrap();
    let yrb = PathBatch::ragged(&yr_data, &yr_lens, d).unwrap();
    for (xb, yb, tag) in [(&xub, &yub, "uniform"), (&xrb, &yrb, "ragged")] {
        let w = weights(&mut rng, xb.batch(), yb.batch());
        for opts in opts_matrix() {
            let want = try_gram_vjp_with_lanes(xb, yb, &w, &opts, 0).unwrap();
            for width in [4usize, 8] {
                let got = try_gram_vjp_with_lanes(xb, yb, &w, &opts, width).unwrap();
                assert_eq!(got, want, "{tag} width={width} opts={opts:?}");
            }
            // The default-width wrapper lands on the same bits too.
            assert_eq!(try_gram_vjp(xb, yb, &w, &opts).unwrap(), want, "{tag} {opts:?}");
        }
    }
}

/// A retained SigKernel record's vjp equals the typed per-pair backward
/// (`try_sig_kernel_vjp`) bit for bit — the record replays Algorithm 4 from
/// its stored grids, the typed path re-solves; same FP sequence either way.
#[test]
fn kernel_record_vjp_bitmatches_the_typed_backward() {
    let mut rng = Rng::new(931);
    let d = 2;
    let b = 6;
    let (x_data, x_lens) = ragged(&mut rng, &[5, 7, 5, 5, 1, 5], d);
    let (y_data, y_lens) = ragged(&mut rng, &[6, 6, 4, 6, 6, 1], d);
    let xb = PathBatch::ragged(&x_data, &x_lens, d).unwrap();
    let yb = PathBatch::ragged(&y_data, &y_lens, d).unwrap();
    let mut cot = vec![0.0; b];
    rng.fill_normal(&mut cot);
    for opts in [
        KernelOptions::default(),
        KernelOptions::default().dyadic(1, 2),
        KernelOptions::default().transform(Transform::LeadLag),
        KernelOptions::default().serial(),
    ] {
        let plan =
            Plan::compile(OpSpec::SigKernel(opts), ShapeClass::for_pair(&xb, &yb)).unwrap();
        let rec = plan.execute_pair(&xb, &yb).unwrap();
        let (gx, gy) = rec.vjp(&cot).unwrap().into_pair().unwrap();
        let xo = xb.element_offsets();
        let yo = yb.element_offsets();
        for i in 0..b {
            let (wx, wy) =
                try_sig_kernel_vjp(xb.path(i), yb.path(i), &opts, cot[i]).unwrap();
            assert_eq!(&gx[xo[i]..xo[i + 1]], &wx[..], "pair {i} x opts={opts:?}");
            assert_eq!(&gy[yo[i]..yo[i + 1]], &wy[..], "pair {i} y opts={opts:?}");
        }
    }
}

/// Gram records compiled at widths 0 / 4 / 8 produce bit-identical vjps,
/// all equal to the typed `try_gram_vjp` on the same weights.
#[test]
fn gram_record_vjp_bitmatches_across_widths() {
    let mut rng = Rng::new(932);
    let d = 2;
    let x = rng.brownian_batch(7, 6, d, 0.4);
    let y = rng.brownian_batch(9, 5, d, 0.4);
    let xb = PathBatch::uniform(&x, 7, 6, d).unwrap();
    let yb = PathBatch::uniform(&y, 9, 5, d).unwrap();
    let w = weights(&mut rng, 7, 9);
    for opts in [KernelOptions::default(), KernelOptions::default().dyadic(1, 1)] {
        let shape = ShapeClass::for_pair(&xb, &yb);
        let want = try_gram_vjp(&xb, &yb, &w, &opts).unwrap();
        for width in [0usize, 4, 8] {
            let plan = Plan::compile(OpSpec::Gram(opts), shape)
                .unwrap()
                .with_lane_width(width);
            let rec = plan.execute_pair(&xb, &yb).unwrap();
            let got = rec.vjp(&w).unwrap().into_pair().unwrap();
            assert_eq!(got, want, "width={width} opts={opts:?}");
        }
    }
}

/// MMD² records (biased and unbiased): the x-gradient is bit-identical
/// across lane widths — including through the symmetric-shortcut Kxx path,
/// which equal dyadic orders always take.
#[test]
fn mmd2_record_vjp_bitmatches_across_widths() {
    let mut rng = Rng::new(933);
    let d = 3;
    let x = rng.brownian_batch(6, 6, d, 0.4);
    let y = rng.brownian_batch(5, 6, d, 0.5);
    let xb = PathBatch::uniform(&x, 6, 6, d).unwrap();
    let yb = PathBatch::uniform(&y, 5, 6, d).unwrap();
    let shape = ShapeClass::for_pair(&xb, &yb);
    for spec in [
        OpSpec::Mmd2(KernelOptions::default()),
        OpSpec::Mmd2Unbiased(KernelOptions::default()),
        OpSpec::Mmd2(KernelOptions::default().dyadic(1, 1)),
        OpSpec::Mmd2(KernelOptions::default().dyadic(1, 2)), // unequal λ: two-slot path
    ] {
        let want = Plan::compile(spec, shape)
            .unwrap()
            .with_lane_width(0)
            .execute_pair(&xb, &yb)
            .unwrap()
            .vjp(&[1.0])
            .unwrap()
            .into_single()
            .unwrap();
        for width in [4usize, 8] {
            let got = Plan::compile(spec, shape)
                .unwrap()
                .with_lane_width(width)
                .execute_pair(&xb, &yb)
                .unwrap()
                .vjp(&[1.0])
                .unwrap()
                .into_single()
                .unwrap();
            assert_eq!(got, want, "spec={} width={width}", spec.name());
        }
    }
}

/// The x-gradient of an MMD² record via the manual two-slot composition:
/// Kxx term through `try_gram_vjp_with_lanes(x, x, ·)` (both slots solved
/// explicitly), plus the cross term — the reference the symmetric shortcut
/// must reproduce.
fn mmd2_grad_two_slot(xb: &PathBatch<'_>, yb: &PathBatch<'_>, opts: &KernelOptions) -> Vec<f64> {
    let (bx, by) = (xb.batch(), yb.batch());
    let wxx = vec![1.0 / (bx * bx) as f64; bx * bx];
    let (gxx1, gxx2) = try_gram_vjp_with_lanes(xb, xb, &wxx, opts, 0).unwrap();
    let wxy = vec![-2.0 / (bx * by) as f64; bx * by];
    let (gxy, _) = try_gram_vjp_with_lanes(xb, yb, &wxy, opts, 0).unwrap();
    gxx1.iter()
        .zip(gxx2.iter())
        .zip(gxy.iter())
        .map(|((a, b), g)| a + b + g)
        .collect()
}

/// The symmetric 2·∇₁ shortcut against the explicit two-slot reference:
/// exact `==` at bx = 2 ∧ λ = 0 (the 2-term sums commute bitwise), ≤ 1e-12
/// relative elsewhere (the per-coarse-cell accumulation order transposes).
#[test]
fn symmetric_shortcut_matches_the_two_slot_path() {
    let mut rng = Rng::new(934);
    let d = 2;
    let y = rng.brownian_batch(3, 5, d, 0.5);
    let yb = PathBatch::uniform(&y, 3, 5, d).unwrap();

    // bx = 2, λ = 0: bit-exact.
    let x2 = rng.brownian_batch(2, 6, d, 0.4);
    let x2b = PathBatch::uniform(&x2, 2, 6, d).unwrap();
    let opts = KernelOptions::default();
    let got = Plan::compile(OpSpec::Mmd2(opts), ShapeClass::for_pair(&x2b, &yb))
        .unwrap()
        .execute_pair(&x2b, &yb)
        .unwrap()
        .vjp(&[1.0])
        .unwrap()
        .into_single()
        .unwrap();
    assert_eq!(got, mmd2_grad_two_slot(&x2b, &yb, &opts), "bx=2 λ=0 must be bit-exact");

    // Larger batch / refined λ: same values to 1e-12 relative.
    let x5 = rng.brownian_batch(5, 6, d, 0.4);
    let x5b = PathBatch::uniform(&x5, 5, 6, d).unwrap();
    for opts in [KernelOptions::default(), KernelOptions::default().dyadic(1, 1)] {
        let got = Plan::compile(OpSpec::Mmd2(opts), ShapeClass::for_pair(&x5b, &yb))
            .unwrap()
            .execute_pair(&x5b, &yb)
            .unwrap()
            .vjp(&[1.0])
            .unwrap()
            .into_single()
            .unwrap();
        let want = mmd2_grad_two_slot(&x5b, &yb, &opts);
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() <= 1e-12 * (1.0 + w.abs()),
                "opts={opts:?} [{i}]: shortcut={g} two-slot={w}"
            );
        }
    }
}

/// Finite differences through the lane path: the width-8 weighted-Gram
/// backward is a true gradient of `Σ w_ij · G_ij`.
#[test]
fn lane_backward_matches_finite_differences() {
    let mut rng = Rng::new(935);
    let d = 2;
    let (bx, by, l) = (3usize, 4usize, 4usize);
    let x = rng.brownian_batch(bx, l, d, 0.4);
    let y = rng.brownian_batch(by, l, d, 0.4);
    let yb = PathBatch::uniform(&y, by, l, d).unwrap();
    let w: Vec<f64> = (0..bx * by).map(|i| 1.0 + 0.1 * i as f64).collect();
    let weighted = |x_data: &[f64]| -> f64 {
        let xb = PathBatch::uniform(x_data, bx, l, d).unwrap();
        let g = try_gram(&xb, &yb, &KernelOptions::default()).unwrap();
        g.iter().zip(w.iter()).map(|(a, b)| a * b).sum()
    };
    let xb = PathBatch::uniform(&x, bx, l, d).unwrap();
    let (gx, _) =
        try_gram_vjp_with_lanes(&xb, &yb, &w, &KernelOptions::default(), 8).unwrap();
    let eps = 1e-6;
    for i in 0..bx * l * d {
        let mut xp = x.clone();
        xp[i] += eps;
        let mut xm = x.clone();
        xm[i] -= eps;
        let fd = (weighted(&xp) - weighted(&xm)) / (2.0 * eps);
        assert!(
            (fd - gx[i]).abs() < 1e-4 * (1.0 + fd.abs()),
            "x[{i}]: fd={fd} vjp={}",
            gx[i]
        );
    }
}
