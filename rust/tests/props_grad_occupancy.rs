//! Grid-reuse acceptance check, isolated in its own integration binary:
//! [`pde_cells_solved`] is a process-global counter, so no other test may
//! share this process. A retained SigKernel record's vjp must replay
//! Algorithm 4 from its stored forward grids — **zero** forward cells
//! solved during the backward.

use pysiglib::engine::{OpSpec, Plan, ShapeClass};
use pysiglib::kernel::{pde_cells_solved, KernelOptions};
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

#[test]
fn kernel_record_vjp_solves_zero_forward_cells() {
    let mut rng = Rng::new(940);
    let d = 2;
    let (b, l) = (5usize, 7usize);
    let x = rng.brownian_batch(b, l, d, 0.4);
    let y = rng.brownian_batch(b, l, d, 0.4);
    let xb = PathBatch::uniform(&x, b, l, d).unwrap();
    let yb = PathBatch::uniform(&y, b, l, d).unwrap();
    for opts in [KernelOptions::default(), KernelOptions::default().dyadic(1, 1).serial()] {
        let plan =
            Plan::compile(OpSpec::SigKernel(opts), ShapeClass::for_pair(&xb, &yb)).unwrap();
        let rec = plan.execute_pair(&xb, &yb).unwrap();
        let before = pde_cells_solved();
        let cot = vec![1.0; b];
        rec.vjp(&cot).unwrap();
        let after = pde_cells_solved();
        assert_eq!(
            after - before,
            0,
            "kernel-record vjp re-solved forward cells (opts={opts:?})"
        );
    }
}
