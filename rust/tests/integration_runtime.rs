//! PJRT ↔ native parity: every AOT artifact must reproduce the native Rust
//! computation to f32 accuracy. Tests skip (pass trivially with a notice)
//! when `artifacts/` has not been built — run `make artifacts` first.

use pysiglib::kernel::KernelOptions;
use pysiglib::runtime::Runtime;
use pysiglib::sig::SigOptions;
use pysiglib::transforms::Transform;
use pysiglib::util::linalg::rel_err;
use pysiglib::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(_) => {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

fn to_f64(v: &[f32]) -> Vec<f64> {
    v.iter().map(|&x| x as f64).collect()
}

#[test]
fn sigkernel_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let (b, l, d) = (8, 16, 3);
    let mut rng = Rng::new(301);
    let x = rng.brownian_batch(b, l, d, 0.3);
    let y = rng.brownian_batch(b, l, d, 0.3);
    let native = pysiglib::kernel::batch_kernel(&x, &y, b, l, l, d, &KernelOptions::default());
    let outs = rt
        .execute_f32("sigkernel_b8_l16_d3", &[to_f32(&x), to_f32(&y)])
        .unwrap();
    let got = to_f64(&outs[0]);
    let e = rel_err(&got, &native);
    assert!(e < 1e-4, "rel err {e}");
}

#[test]
fn sigkernel_vjp_artifact_matches_native_gradients() {
    let Some(rt) = runtime() else { return };
    let (b, l, d) = (4, 16, 3);
    let mut rng = Rng::new(302);
    let x = rng.brownian_batch(b, l, d, 0.3);
    let y = rng.brownian_batch(b, l, d, 0.3);
    let outs = rt
        .execute_f32("sigkernel_vjp_b4_l16_d3", &[to_f32(&x), to_f32(&y)])
        .unwrap();
    assert_eq!(outs.len(), 3, "k, gx, gy");
    let gk = vec![1.0; b];
    let (gx, gy) = pysiglib::kernel::batch_kernel_vjp(
        &x,
        &y,
        &gk,
        b,
        l,
        l,
        d,
        &KernelOptions::default(),
    );
    let e1 = rel_err(&to_f64(&outs[1]), &gx);
    let e2 = rel_err(&to_f64(&outs[2]), &gy);
    assert!(e1 < 1e-3 && e2 < 1e-3, "grad rel errs {e1} {e2}");
}

#[test]
fn signature_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let (b, l, d, n) = (8, 32, 2, 4);
    let mut rng = Rng::new(303);
    let paths = rng.brownian_batch(b, l, d, 0.3);
    let native = pysiglib::sig::batch_signature(&paths, b, l, d, &SigOptions::new(n));
    let outs = rt
        .execute_f32("signature_b8_l32_d2_n4", &[to_f32(&paths)])
        .unwrap();
    let e = rel_err(&to_f64(&outs[0]), &native);
    assert!(e < 1e-4, "rel err {e}");
}

#[test]
fn leadlag_signature_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let (b, l, d, n) = (8, 16, 2, 3);
    let mut rng = Rng::new(304);
    let paths = rng.brownian_batch(b, l, d, 0.3);
    let native = pysiglib::sig::batch_signature(
        &paths,
        b,
        l,
        d,
        &SigOptions::new(n).transform(Transform::LeadLag),
    );
    let outs = rt
        .execute_f32("signature_leadlag_b8_l16_d2_n3", &[to_f32(&paths)])
        .unwrap();
    let e = rel_err(&to_f64(&outs[0]), &native);
    assert!(e < 1e-4, "rel err {e}");
}

#[test]
fn mmd_grad_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let (b, l, d) = (4, 12, 2);
    let mut rng = Rng::new(305);
    let x = rng.brownian_batch(b, l, d, 0.3);
    let y = rng.brownian_batch(b, l, d, 0.3);
    let outs = rt
        .execute_f32("mmd2_grad_b4_l12_d2", &[to_f32(&x), to_f32(&y)])
        .unwrap();
    let (val, grad) =
        pysiglib::kernel::mmd2_with_grad(&x, &y, b, b, l, l, d, &KernelOptions::default());
    let got_val = outs[0][0] as f64;
    assert!(
        (got_val - val).abs() < 1e-4 * (1.0 + val.abs()),
        "mmd {got_val} vs {val}"
    );
    let e = rel_err(&to_f64(&outs[1]), &grad);
    assert!(e < 1e-3, "grad rel err {e}");
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(rt) = runtime() else { return };
    assert!(rt.execute_f32("nope", &[]).is_err());
}

#[test]
fn wrong_input_shape_is_rejected_before_dispatch() {
    let Some(rt) = runtime() else { return };
    let r = rt.execute_f32("sigkernel_b8_l16_d3", &[vec![0.0; 3], vec![0.0; 3]]);
    assert!(r.is_err());
}
