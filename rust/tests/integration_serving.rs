//! End-to-end serving tests: TCP server + dynamic batcher + router, driven
//! by real clients over loopback, checked against direct native computation.

use std::sync::Arc;
use std::time::Duration;

use pysiglib::coordinator::{serve, Batcher, BatcherConfig, Client, Op, Router};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;

fn start_server(
    max_batch: usize,
    max_wait_us: u64,
) -> (
    pysiglib::coordinator::server::ServerHandle,
    std::net::SocketAddr,
    Arc<Batcher>,
) {
    let router = Arc::new(Router::native_only());
    let batcher = Arc::new(Batcher::start(
        router,
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_micros(max_wait_us),
            ..BatcherConfig::default()
        },
    ));
    let handle = serve("127.0.0.1:0", batcher.clone()).expect("bind");
    let addr = handle.addr;
    (handle, addr, batcher)
}

#[test]
fn signature_request_roundtrip_matches_native() {
    let (_h, addr, _b) = start_server(8, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(100);
    let path = rng.brownian_path(12, 3, 0.5);
    let resp = client.signature(&path, 12, 3, 4).unwrap().unwrap();
    let want = pysiglib::sig::sig(&path, 12, 3, 4);
    assert_eq!(resp.len(), want.len());
    let err = pysiglib::util::linalg::max_abs_diff(&resp, &want);
    assert!(err < 1e-12, "served vs native: {err}");
}

#[test]
fn kernel_request_roundtrip_matches_native() {
    let (_h, addr, _b) = start_server(8, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(101);
    let x = rng.brownian_path(10, 2, 0.5);
    let y = rng.brownian_path(10, 2, 0.5);
    let k = client.sig_kernel(&x, &y, 10, 2).unwrap().unwrap();
    let want = pysiglib::kernel::sig_kernel(
        &x,
        &y,
        10,
        10,
        2,
        &pysiglib::kernel::KernelOptions::default(),
    );
    assert!((k - want).abs() < 1e-12, "{k} vs {want}");
}

#[test]
fn concurrent_clients_all_served_correctly() {
    let (_h, addr, batcher) = start_server(16, 2000);
    let n_clients = 8;
    let per_client = 12;
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut rng = Rng::new(200 + c as u64);
                for _ in 0..per_client {
                    let path = rng.brownian_path(16, 2, 0.5);
                    let resp = client.signature(&path, 16, 2, 3).unwrap().unwrap();
                    let want = pysiglib::sig::sig(&path, 16, 2, 3);
                    let err = pysiglib::util::linalg::max_abs_diff(&resp, &want);
                    assert!(err < 1e-12);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let total = batcher
        .metrics
        .responses_total
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(total, (n_clients * per_client) as u64);
    // With identical shapes and concurrent clients, batching must engage.
    assert!(
        batcher.metrics.mean_batch_size() >= 1.0,
        "mean batch {}",
        batcher.metrics.mean_batch_size()
    );
}

#[test]
fn transform_and_grad_ops_over_the_wire() {
    let (_h, addr, _b) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(102);
    let x = rng.brownian_path(8, 2, 0.5);
    // Lead-lag signature.
    let resp = client
        .call(
            Op::Signature {
                depth: 3,
                transform: pysiglib::coordinator::transform_to_u8(Transform::LeadLag),
            },
            8,
            2,
            x.clone(),
        )
        .unwrap()
        .unwrap();
    let want = pysiglib::sig::signature(
        &x,
        8,
        2,
        3,
        Transform::LeadLag,
        pysiglib::sig::SigMethod::Horner,
    );
    assert!(pysiglib::util::linalg::max_abs_diff(&resp, &want) < 1e-12);
    // Kernel gradient returns grad_x ++ grad_y.
    let y = rng.brownian_path(8, 2, 0.5);
    let mut values = x.clone();
    values.extend_from_slice(&y);
    let resp = client
        .call(
            Op::SigKernelGrad {
                lam1: 0,
                lam2: 0,
                scheme: 0,
            },
            8,
            2,
            values,
        )
        .unwrap()
        .unwrap();
    assert_eq!(resp.len(), 2 * 8 * 2);
    let (gx, gy) = pysiglib::kernel::sig_kernel_vjp(
        &x,
        &y,
        8,
        8,
        2,
        &pysiglib::kernel::KernelOptions::default(),
        1.0,
    );
    assert!(pysiglib::util::linalg::max_abs_diff(&resp[..16], &gx) < 1e-12);
    assert!(pysiglib::util::linalg::max_abs_diff(&resp[16..], &gy) < 1e-12);
}

/// Repeated same-shape-group traffic is served through the router's LRU
/// plan cache: after several flushes of the same (op, len, dim) class, the
/// hit counter surfaced in the server metrics snapshot must be positive.
#[test]
fn repeated_shape_groups_hit_the_plan_cache() {
    let (_h, addr, batcher) = start_server(4, 300);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(110);
    // Sequential requests ⇒ each flush is its own batch; the first compiles
    // the shape group's plan, later ones reuse it.
    for _ in 0..4 {
        let path = rng.brownian_path(14, 2, 0.5);
        let resp = client.signature(&path, 14, 2, 3).unwrap().unwrap();
        assert_eq!(resp.len(), pysiglib::sig::sig_length(2, 3));
    }
    let hits = batcher
        .metrics
        .plan_hits_total
        .load(std::sync::atomic::Ordering::Relaxed);
    let misses = batcher
        .metrics
        .plan_misses_total
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(hits > 0, "plan cache hits must be observed (misses={misses})");
    assert!(misses >= 1, "first request of the class compiles");
    assert!(batcher.metrics.summary().contains("plan_hits="));
}

#[test]
fn malformed_payload_gets_error_response() {
    let (_h, addr, _b) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let r = client
        .call(
            Op::Signature {
                depth: 3,
                transform: 0,
            },
            10,
            2,
            vec![1.0; 7], // wrong size
        )
        .unwrap();
    assert!(r.is_err());
    // The connection stays usable afterwards.
    let mut rng = Rng::new(103);
    let path = rng.brownian_path(10, 2, 0.5);
    assert!(client.signature(&path, 10, 2, 2).unwrap().is_ok());
}

/// The no-panic contract: every malformed-but-framed request — zero dim,
/// zero length, unknown op code, unknown transform, shape-inconsistent
/// header — yields an `Err` response and the server keeps serving on the
/// same connection.
#[test]
fn malformed_frames_error_and_server_keeps_serving() {
    use std::io::Write;
    let (_h, addr, _b) = start_server(4, 500);
    let mut stream = std::net::TcpStream::connect(addr).unwrap();

    // Hand-crafted frames: (header words after magic, payload values).
    // Header: op, p1, p2, transform, len, dim, n_values.
    let cases: [([u32; 7], usize); 5] = [
        ([1, 3, 0, 0, 2, 0, 0], 0),  // zero dim
        ([1, 3, 0, 0, 0, 2, 0], 0),  // zero len
        ([9, 3, 0, 0, 2, 2, 4], 4),  // unknown op code
        ([1, 3, 0, 9, 2, 2, 4], 4),  // unknown transform
        ([1, 3, 0, 0, 4, 2, 3], 3),  // n_values != len·dim
    ];
    for (words, n) in &cases {
        let mut buf = Vec::new();
        buf.extend_from_slice(&pysiglib::coordinator::wire::MAGIC.to_le_bytes());
        for w in words {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        for v in 0..*n {
            buf.extend_from_slice(&(v as f64).to_le_bytes());
        }
        stream.write_all(&buf).unwrap();
        let resp = pysiglib::coordinator::wire::read_response(&mut stream).unwrap();
        assert!(resp.is_err(), "case {words:?} should error: {resp:?}");
    }

    // Same connection still serves a well-formed request.
    let mut rng = Rng::new(104);
    let path = rng.brownian_path(8, 2, 0.5);
    let frame = pysiglib::coordinator::Frame {
        op: Op::Signature {
            depth: 3,
            transform: 0,
        },
        len: 8,
        dim: 2,
        values: path.clone(),
    };
    pysiglib::coordinator::wire::write_request(&mut stream, &frame).unwrap();
    let resp = pysiglib::coordinator::wire::read_response(&mut stream).unwrap().unwrap();
    let want = pysiglib::sig::sig(&path, 8, 2, 3);
    assert!(pysiglib::util::linalg::max_abs_diff(&resp, &want) < 1e-12);
}

/// Ragged batch frames round-trip: one request carries paths of different
/// lengths and the response matches per-path native computation exactly.
#[test]
fn ragged_batch_signature_over_the_wire() {
    let (_h, addr, _b) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(105);
    let d = 2;
    let lengths = [6usize, 1, 11, 3];
    let paths: Vec<Vec<f64>> = lengths
        .iter()
        .map(|&l| rng.brownian_path(l, d, 0.5))
        .collect();
    let refs: Vec<&[f64]> = paths.iter().map(|p| p.as_slice()).collect();
    let resp = client
        .batch_signature_ragged(&refs, d, 3)
        .unwrap()
        .unwrap();
    let slen = pysiglib::sig::sig_length(d, 3);
    assert_eq!(resp.len(), lengths.len() * slen);
    for (i, p) in paths.iter().enumerate() {
        let want = pysiglib::sig::sig(p, lengths[i], d, 3);
        assert_eq!(&resp[i * slen..(i + 1) * slen], &want[..], "path {i}");
    }
}

#[test]
fn ragged_kernel_pairs_over_the_wire() {
    let (_h, addr, _b) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(106);
    let d = 2;
    let shapes = [(5usize, 9usize), (3, 3), (12, 2)];
    let data: Vec<(Vec<f64>, Vec<f64>)> = shapes
        .iter()
        .map(|&(lx, ly)| (rng.brownian_path(lx, d, 0.4), rng.brownian_path(ly, d, 0.4)))
        .collect();
    let pairs: Vec<(&[f64], &[f64])> = data
        .iter()
        .map(|(x, y)| (x.as_slice(), y.as_slice()))
        .collect();
    let resp = client.sig_kernel_ragged(&pairs, d).unwrap().unwrap();
    assert_eq!(resp.len(), shapes.len());
    for (i, ((x, y), &(lx, ly))) in data.iter().zip(shapes.iter()).enumerate() {
        let want = pysiglib::kernel::sig_kernel(
            x,
            y,
            lx,
            ly,
            d,
            &pysiglib::kernel::KernelOptions::default(),
        );
        assert_eq!(resp[i], want, "pair {i}");
    }
}

/// Low-rank MMD² over the wire: the rank field reaches the engine, the
/// response matches direct computation with the wire's fixed seed, and a
/// bad corpus split is an error response, not a dead connection.
#[test]
fn lowrank_mmd_over_the_wire() {
    use pysiglib::engine::{OpSpec, Plan, ShapeClass};
    use pysiglib::kernel::{KernelOptions, LowRankSpec};
    use pysiglib::PathBatch;

    let (_h, addr, _b) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(108);
    let d = 2;
    let xs: Vec<Vec<f64>> = [5usize, 7, 6]
        .iter()
        .map(|&l| rng.brownian_path(l, d, 0.4))
        .collect();
    let ys: Vec<Vec<f64>> = [6usize, 4, 8, 5]
        .iter()
        .map(|&l| rng.brownian_path(l, d, 0.5))
        .collect();
    let xrefs: Vec<&[f64]> = xs.iter().map(|p| p.as_slice()).collect();
    let yrefs: Vec<&[f64]> = ys.iter().map(|p| p.as_slice()).collect();
    let rank = 3u32;
    let got = client.mmd2_lowrank(&xrefs, &yrefs, d, rank).unwrap().unwrap();

    // Reference: the same engine plan with the wire's fixed seed.
    let (mut xflat, mut yflat) = (Vec::new(), Vec::new());
    for p in &xs {
        xflat.extend_from_slice(p);
    }
    for p in &ys {
        yflat.extend_from_slice(p);
    }
    let xb = PathBatch::ragged(&xflat, &[5, 7, 6], d).unwrap();
    let yb = PathBatch::ragged(&yflat, &[6, 4, 8, 5], d).unwrap();
    let plan = Plan::compile_forward(
        OpSpec::Mmd2LowRank {
            opts: KernelOptions::default(),
            lowrank: LowRankSpec::nystrom(
                rank as usize,
                pysiglib::coordinator::WIRE_LOWRANK_SEED,
            ),
        },
        ShapeClass::for_pair(&xb, &yb).bucketed(),
    )
    .unwrap();
    let want = plan.execute_pair(&xb, &yb).unwrap().value();
    assert_eq!(got, want);

    // nx = 0 (empty x corpus) is a soft error; the connection keeps serving.
    let r = client
        .call_ragged(
            Op::Mmd2LowRank {
                rank,
                nx: 0,
                transform: 0,
            },
            d,
            vec![5, 7],
            vec![0.0; 24],
        )
        .unwrap();
    assert!(r.is_err());
    let path = rng.brownian_path(6, 2, 0.5);
    assert!(client.signature(&path, 6, 2, 2).unwrap().is_ok());
}

/// The corpus lifecycle over the wire: register (deduplicated) → query cold
/// and warm (bit-identical) → append → re-query, matching the router's
/// registry driven directly; unknown ids are soft errors.
#[test]
fn corpus_lifecycle_over_the_wire() {
    let (_h, addr, batcher) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(120);
    let d = 2;
    let corpus: Vec<Vec<f64>> = [6usize, 4, 7, 5]
        .iter()
        .map(|&l| rng.brownian_path(l, d, 0.4))
        .collect();
    let crefs: Vec<&[f64]> = corpus.iter().map(|p| p.as_slice()).collect();
    let id = client.register_corpus(&crefs, d).unwrap().unwrap();
    let again = client.register_corpus(&crefs, d).unwrap().unwrap();
    assert_eq!(id, again, "re-registration must deduplicate");
    let queries: Vec<Vec<f64>> = [5usize, 6]
        .iter()
        .map(|&l| rng.brownian_path(l, d, 0.5))
        .collect();
    let qrefs: Vec<&[f64]> = queries.iter().map(|p| p.as_slice()).collect();
    let cold = client.mmd2_corpus(id, &qrefs, d, 0).unwrap().unwrap();
    let warm = client.mmd2_corpus(id, &qrefs, d, 0).unwrap().unwrap();
    assert_eq!(cold, warm, "warm corpus re-query must be bit-identical");
    let extra = rng.brownian_path(5, d, 0.4);
    let total = client
        .append_corpus(id, &[extra.as_slice()], d)
        .unwrap()
        .unwrap();
    assert_eq!(total, 5);
    let post = client.mmd2_corpus(id, &qrefs, d, 0).unwrap().unwrap();
    assert_ne!(post, cold, "appending must change the estimate");
    // Low-rank rank field reaches the registry route too.
    let lr = client.mmd2_corpus(id, &qrefs, d, 3).unwrap().unwrap();
    assert!(lr.is_finite());
    // Unknown id: soft error, connection keeps serving.
    assert!(client.mmd2_corpus(9999, &qrefs, d, 0).unwrap().is_err());
    let path = rng.brownian_path(6, d, 0.5);
    assert!(client.signature(&path, 6, d, 2).unwrap().is_ok());
    // Registry counters are mirrored into the server metrics.
    let m = &batcher.metrics;
    assert_eq!(
        m.corpus_registered_total
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    assert!(
        m.corpus_warm_hits_total
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 1
    );
}

/// The streaming lifecycle over the wire: extend a registered path in
/// place, score an exponentially-weighted window MMD², and evict down to a
/// sliding window — with malformed stream frames answered as soft errors.
#[test]
fn streaming_ops_over_the_wire() {
    let (_h, addr, _batcher) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(121);
    let d = 2;
    let corpus: Vec<Vec<f64>> = [6usize, 4, 7]
        .iter()
        .map(|&l| rng.brownian_path(l, d, 0.4))
        .collect();
    let crefs: Vec<&[f64]> = corpus.iter().map(|p| p.as_slice()).collect();
    let id = client.register_corpus(&crefs, d).unwrap().unwrap();
    // Extend path 0 by two points: 6 → 8.
    let extra = rng.brownian_path(2, d, 0.4);
    let new_len = client.extend_path(id, 0, &extra, d).unwrap().unwrap();
    assert_eq!(new_len, 8);
    // Window MMD²: decay 10000 bp (uniform) and 9000 bp both serve.
    let window: Vec<Vec<f64>> = [5usize, 6]
        .iter()
        .map(|&l| rng.brownian_path(l, d, 0.5))
        .collect();
    let wrefs: Vec<&[f64]> = window.iter().map(|p| p.as_slice()).collect();
    let uniform = client.mmd2_window(id, &wrefs, d, 10_000).unwrap().unwrap();
    let decayed = client.mmd2_window(id, &wrefs, d, 9_000).unwrap().unwrap();
    assert!(uniform.is_finite() && decayed.is_finite());
    assert_ne!(uniform, decayed, "decay must reweight the window estimate");
    // Evict down to the newest 2 paths.
    let kept = client.evict_corpus(id, 2, d).unwrap().unwrap();
    assert_eq!(kept, 2);
    // Age-based eviction over the wire: the survivors were all present
    // before the last append tick, so a generous age bound keeps both,
    // and the keep floor backstops an aggressive one.
    let kept = client.evict_corpus_by_age(id, 1_000, 0, d).unwrap().unwrap();
    assert_eq!(kept, 2);
    let kept = client.evict_corpus_by_age(id, 1, 1, d).unwrap().unwrap();
    assert!(kept >= 1);
    // Malformed stream frames are soft errors; the connection keeps serving.
    assert!(client
        .call_ragged(
            Op::EvictCorpus {
                id,
                keep: 0,
                max_age: 0,
            },
            d,
            vec![],
            vec![],
        )
        .unwrap()
        .is_err());
    assert!(client
        .call_ragged(
            Op::Mmd2Window {
                id,
                decay_bp: 20_000,
                transform: 0,
            },
            d,
            vec![2],
            vec![0.0; 4],
        )
        .unwrap()
        .is_err());
    assert!(client.extend_path(9999, 0, &extra, d).unwrap().is_err());
    let still = client.mmd2_window(id, &wrefs, d, 10_000).unwrap().unwrap();
    assert!(still.is_finite());
}

/// Satellite: the metrics surface under a serving sequence mixing
/// corpus-warm, corpus-cold and plain requests — per-op counters, plan
/// cache hit/miss/eviction and the corpus warm/cold mirrors all move
/// correctly.
#[test]
fn metrics_track_per_op_and_cache_counters_across_mixed_serving() {
    use std::sync::atomic::Ordering;
    let (_h, addr, batcher) = start_server(4, 300);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(121);
    let d = 2;
    let m = &batcher.metrics;

    // 1) Plain signature traffic: op 1, plan-cache miss then hits.
    for _ in 0..3 {
        let path = rng.brownian_path(10, d, 0.5);
        client.signature(&path, 10, d, 3).unwrap().unwrap();
    }
    assert_eq!(m.op_count(1), 3);
    let sig_hits = m.plan_hits_total.load(Ordering::Relaxed);
    let sig_misses = m.plan_misses_total.load(Ordering::Relaxed);
    assert!(sig_misses >= 1, "first signature flush compiles its plan");
    assert!(sig_hits >= 1, "repeat signature flushes hit the plan cache");

    // 2) Corpus lifecycle: register (op 7), cold query, warm query (op 9).
    // 12 equal-length paths: the self-Gram's tile rows hold a full W = 8
    // lane group plus a scalar remainder, so the occupancy mirrors must
    // move below.
    let corpus: Vec<Vec<f64>> = (0..12).map(|_| rng.brownian_path(6, d, 0.4)).collect();
    let crefs: Vec<&[f64]> = corpus.iter().map(|p| p.as_slice()).collect();
    let id = client.register_corpus(&crefs, d).unwrap().unwrap();
    assert_eq!(m.op_count(7), crefs.len() as u64, "register counts its paths");
    let queries: Vec<Vec<f64>> = (0..2).map(|_| rng.brownian_path(7, d, 0.5)).collect();
    let qrefs: Vec<&[f64]> = queries.iter().map(|p| p.as_slice()).collect();
    client.mmd2_corpus(id, &qrefs, d, 0).unwrap().unwrap();
    let cold_after_first = m.corpus_cold_builds_total.load(Ordering::Relaxed);
    let warm_after_first = m.corpus_warm_hits_total.load(Ordering::Relaxed);
    assert_eq!(cold_after_first, 1, "first corpus query builds the self-Gram");
    assert_eq!(warm_after_first, 0);
    client.mmd2_corpus(id, &qrefs, d, 0).unwrap().unwrap();
    assert_eq!(
        m.corpus_cold_builds_total.load(Ordering::Relaxed),
        1,
        "warm re-query must not rebuild"
    );
    assert_eq!(m.corpus_warm_hits_total.load(Ordering::Relaxed), 1);
    assert_eq!(m.op_count(9), 2 * qrefs.len() as u64);
    // Tile/lane occupancy mirrors (satellite of the lane engine): the
    // corpus self-Gram ran through the tile scheduler, its uniform-length
    // rows packed full lane groups, and 12 % 8 columns fell to the scalar
    // remainder. The sources are process-wide (sibling tests may add), so
    // these are floor assertions — this test's own traffic guarantees each
    // counter moved regardless of interleaving.
    assert!(
        m.tiles_executed_total.load(Ordering::Relaxed) > 0,
        "corpus self-Gram must execute tiles"
    );
    assert!(
        m.lane_groups_total.load(Ordering::Relaxed) > 0,
        "uniform 12-path corpus must dispatch lane groups"
    );
    assert!(
        m.lane_scalar_pairs_total.load(Ordering::Relaxed) > 0,
        "12 % 8 columns per row must fall to the scalar remainder"
    );
    // The corpus plan compiled once and was cache-hit on the re-query.
    assert!(m.plan_misses_total.load(Ordering::Relaxed) > sig_misses);
    assert!(m.plan_hits_total.load(Ordering::Relaxed) > sig_hits);

    // 3) Append (op 8) then an error request: error counter moves, per-op
    //    counters still track.
    let extra = rng.brownian_path(6, d, 0.4);
    client
        .append_corpus(id, &[extra.as_slice()], d)
        .unwrap()
        .unwrap();
    assert_eq!(m.op_count(8), 1);
    let errors_before = m.errors_total.load(Ordering::Relaxed);
    assert!(client.mmd2_corpus(777, &qrefs, d, 0).unwrap().is_err());
    assert!(m.errors_total.load(Ordering::Relaxed) > errors_before);

    // Every request got exactly one response, and the summary carries the
    // new fields.
    assert_eq!(
        m.requests_total.load(Ordering::Relaxed),
        m.responses_total.load(Ordering::Relaxed)
    );
    let s = m.summary();
    assert!(s.contains("corpus_warm="), "{s}");
    assert!(s.contains("op9="), "{s}");
    assert!(s.contains("lane_groups="), "{s}");
    assert!(s.contains("tiles="), "{s}");
}

/// A malformed ragged frame (lengths disagreeing with the payload) errors
/// without killing the connection.
#[test]
fn malformed_ragged_frame_gets_error_response() {
    let (_h, addr, _b) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let r = client
        .call_ragged(
            Op::Signature {
                depth: 3,
                transform: 0,
            },
            2,
            vec![3, 2],      // 5 points → 10 values expected
            vec![0.0; 9], // one short
        )
        .unwrap();
    assert!(r.is_err());
    let mut rng = Rng::new(107);
    let path = rng.brownian_path(6, 2, 0.5);
    assert!(client.signature(&path, 6, 2, 2).unwrap().is_ok());
}
