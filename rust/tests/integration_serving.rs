//! End-to-end serving tests: TCP server + dynamic batcher + router, driven
//! by real clients over loopback, checked against direct native computation.

use std::sync::Arc;
use std::time::Duration;

use pysiglib::coordinator::{serve, Batcher, BatcherConfig, Client, Op, Router};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;

fn start_server(max_batch: usize, max_wait_us: u64) -> (pysiglib::coordinator::server::ServerHandle, std::net::SocketAddr, Arc<Batcher>) {
    let router = Arc::new(Router::native_only());
    let batcher = Arc::new(Batcher::start(
        router,
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_micros(max_wait_us),
        },
    ));
    let handle = serve("127.0.0.1:0", batcher.clone()).expect("bind");
    let addr = handle.addr;
    (handle, addr, batcher)
}

#[test]
fn signature_request_roundtrip_matches_native() {
    let (_h, addr, _b) = start_server(8, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(100);
    let path = rng.brownian_path(12, 3, 0.5);
    let resp = client.signature(&path, 12, 3, 4).unwrap().unwrap();
    let want = pysiglib::sig::sig(&path, 12, 3, 4);
    assert_eq!(resp.len(), want.len());
    let err = pysiglib::util::linalg::max_abs_diff(&resp, &want);
    assert!(err < 1e-12, "served vs native: {err}");
}

#[test]
fn kernel_request_roundtrip_matches_native() {
    let (_h, addr, _b) = start_server(8, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(101);
    let x = rng.brownian_path(10, 2, 0.5);
    let y = rng.brownian_path(10, 2, 0.5);
    let k = client.sig_kernel(&x, &y, 10, 2).unwrap().unwrap();
    let want = pysiglib::kernel::sig_kernel(
        &x,
        &y,
        10,
        10,
        2,
        &pysiglib::kernel::KernelOptions::default(),
    );
    assert!((k - want).abs() < 1e-12, "{k} vs {want}");
}

#[test]
fn concurrent_clients_all_served_correctly() {
    let (_h, addr, batcher) = start_server(16, 2000);
    let n_clients = 8;
    let per_client = 12;
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut rng = Rng::new(200 + c as u64);
                for _ in 0..per_client {
                    let path = rng.brownian_path(16, 2, 0.5);
                    let resp = client.signature(&path, 16, 2, 3).unwrap().unwrap();
                    let want = pysiglib::sig::sig(&path, 16, 2, 3);
                    let err = pysiglib::util::linalg::max_abs_diff(&resp, &want);
                    assert!(err < 1e-12);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let total = batcher
        .metrics
        .responses_total
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(total, (n_clients * per_client) as u64);
    // With identical shapes and concurrent clients, batching must engage.
    assert!(
        batcher.metrics.mean_batch_size() >= 1.0,
        "mean batch {}",
        batcher.metrics.mean_batch_size()
    );
}

#[test]
fn transform_and_grad_ops_over_the_wire() {
    let (_h, addr, _b) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(102);
    let x = rng.brownian_path(8, 2, 0.5);
    // Lead-lag signature.
    let resp = client
        .call(
            Op::Signature {
                depth: 3,
                transform: pysiglib::coordinator::transform_to_u8(Transform::LeadLag),
            },
            8,
            2,
            x.clone(),
        )
        .unwrap()
        .unwrap();
    let want = pysiglib::sig::signature(
        &x,
        8,
        2,
        3,
        Transform::LeadLag,
        pysiglib::sig::SigMethod::Horner,
    );
    assert!(pysiglib::util::linalg::max_abs_diff(&resp, &want) < 1e-12);
    // Kernel gradient returns grad_x ++ grad_y.
    let y = rng.brownian_path(8, 2, 0.5);
    let mut values = x.clone();
    values.extend_from_slice(&y);
    let resp = client
        .call(Op::SigKernelGrad { lam1: 0, lam2: 0 }, 8, 2, values)
        .unwrap()
        .unwrap();
    assert_eq!(resp.len(), 2 * 8 * 2);
    let (gx, gy) = pysiglib::kernel::sig_kernel_vjp(
        &x,
        &y,
        8,
        8,
        2,
        &pysiglib::kernel::KernelOptions::default(),
        1.0,
    );
    assert!(pysiglib::util::linalg::max_abs_diff(&resp[..16], &gx) < 1e-12);
    assert!(pysiglib::util::linalg::max_abs_diff(&resp[16..], &gy) < 1e-12);
}

#[test]
fn malformed_payload_gets_error_response() {
    let (_h, addr, _b) = start_server(4, 500);
    let mut client = Client::connect(addr).unwrap();
    let r = client
        .call(
            Op::Signature {
                depth: 3,
                transform: 0,
            },
            10,
            2,
            vec![1.0; 7], // wrong size
        )
        .unwrap();
    assert!(r.is_err());
    // The connection stays usable afterwards.
    let mut rng = Rng::new(103);
    let path = rng.brownian_path(10, 2, 0.5);
    assert!(client.signature(&path, 10, 2, 2).unwrap().is_ok());
}
