//! Engine-layer acceptance tests: `ExecutionRecord::vjp` gradients for the
//! full vjp family (signature, sig_kernel, gram, mmd2) against central
//! finite differences AND bit-for-bit against the pre-existing
//! `sig::backward` / `kernel::backward` entry points; plus the
//! plan-cached-vs-one-shot bit-identity property on uniform and ragged
//! batches.

use pysiglib::engine::{Gradients, OpSpec, Plan, Session, ShapeClass};
use pysiglib::kernel::KernelOptions;
use pysiglib::sig::{sig_length, SigOptions};
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

fn fd_check(fd: f64, got: f64, what: &str) {
    assert!(
        (fd - got).abs() < 1e-6 * (1.0 + fd.abs()),
        "{what}: finite difference {fd} vs vjp {got}"
    );
}

#[test]
fn signature_record_vjp_matches_fd_and_backward_bitwise() {
    let mut rng = Rng::new(300);
    let (b, l, d, depth) = (3usize, 5usize, 2usize, 3usize);
    let data = rng.brownian_batch(b, l, d, 0.4);
    let pb = PathBatch::uniform(&data, b, l, d).unwrap();
    let opts = SigOptions::new(depth);
    let slen = sig_length(d, depth);
    let mut cot = vec![0.0; b * slen];
    rng.fill_normal(&mut cot);

    let plan = Plan::compile(OpSpec::Sig(opts), ShapeClass::uniform(d, l)).unwrap();
    let rec = plan.execute(&pb).unwrap();
    let gx = match rec.vjp(&cot).unwrap() {
        Gradients::Single(g) => g,
        _ => panic!("signature vjp is single-input"),
    };

    // Bit-for-bit identical to the pre-existing backward entry point.
    for i in 0..b {
        let want = pysiglib::sig::signature_vjp(
            &data[i * l * d..(i + 1) * l * d],
            l,
            d,
            depth,
            pysiglib::transforms::Transform::None,
            &cot[i * slen..(i + 1) * slen],
        );
        assert_eq!(&gx[i * l * d..(i + 1) * l * d], &want[..], "path {i}");
    }

    // Central finite differences on F = Σ_i <cot_i, S(x_i)>.
    let f = |paths: &[f64]| -> f64 {
        let pb = PathBatch::uniform(paths, b, l, d).unwrap();
        let sigs = pysiglib::sig::try_batch_signature(&pb, &opts).unwrap();
        sigs.iter().zip(cot.iter()).map(|(a, c)| a * c).sum()
    };
    let eps = 1e-5;
    for idx in 0..b * l * d {
        let mut p = data.clone();
        p[idx] += eps;
        let fp = f(&p);
        p[idx] -= 2.0 * eps;
        let fm = f(&p);
        fd_check((fp - fm) / (2.0 * eps), gx[idx], "signature");
    }
}

#[test]
fn sig_kernel_record_vjp_matches_fd_and_backward_bitwise() {
    let mut rng = Rng::new(301);
    let (b, d) = (3usize, 2usize);
    let xl = [4usize, 1, 5];
    let yl = [5usize, 3, 4];
    let (mut xdata, mut ydata) = (Vec::new(), Vec::new());
    for &l in &xl {
        xdata.extend(rng.brownian_path(l, d, 0.4));
    }
    for &l in &yl {
        ydata.extend(rng.brownian_path(l, d, 0.4));
    }
    let xb = PathBatch::ragged(&xdata, &xl, d).unwrap();
    let yb = PathBatch::ragged(&ydata, &yl, d).unwrap();
    let opts = KernelOptions::default().dyadic(1, 0);
    let mut cot = vec![0.0; b];
    rng.fill_normal(&mut cot);

    let plan = Plan::compile(OpSpec::SigKernel(opts), ShapeClass::for_pair(&xb, &yb)).unwrap();
    let rec = plan.execute_pair(&xb, &yb).unwrap();
    // Forward values bit-match the one-shot kernel.
    let ks = pysiglib::kernel::try_batch_kernel(&xb, &yb, &opts).unwrap();
    assert_eq!(rec.values(), &ks[..]);
    let (gx, gy) = match rec.vjp(&cot).unwrap() {
        Gradients::Pair(gx, gy) => (gx, gy),
        _ => panic!("kernel vjp is pair-input"),
    };

    // Bit-for-bit identical to the pre-existing Algorithm-4 entry point.
    let xo = xb.element_offsets();
    let yo = yb.element_offsets();
    for i in 0..b {
        let (wx, wy) = pysiglib::kernel::try_sig_kernel_vjp(
            xb.path(i),
            yb.path(i),
            &opts,
            cot[i],
        )
        .unwrap();
        assert_eq!(&gx[xo[i]..xo[i + 1]], &wx[..], "pair {i} grad_x");
        assert_eq!(&gy[yo[i]..yo[i + 1]], &wy[..], "pair {i} grad_y");
    }

    // Central finite differences on F = Σ_i cot_i · k(x_i, y_i).
    let f = |xs: &[f64], ys: &[f64]| -> f64 {
        let xb = PathBatch::ragged(xs, &xl, d).unwrap();
        let yb = PathBatch::ragged(ys, &yl, d).unwrap();
        let ks = pysiglib::kernel::try_batch_kernel(&xb, &yb, &opts).unwrap();
        ks.iter().zip(cot.iter()).map(|(k, c)| k * c).sum()
    };
    let eps = 1e-6;
    for idx in 0..xdata.len() {
        let mut p = xdata.clone();
        p[idx] += eps;
        let fp = f(&p, &ydata);
        p[idx] -= 2.0 * eps;
        let fm = f(&p, &ydata);
        fd_check((fp - fm) / (2.0 * eps), gx[idx], "kernel grad_x");
    }
    for idx in 0..ydata.len() {
        let mut p = ydata.clone();
        p[idx] += eps;
        let fp = f(&xdata, &p);
        p[idx] -= 2.0 * eps;
        let fm = f(&xdata, &p);
        fd_check((fp - fm) / (2.0 * eps), gy[idx], "kernel grad_y");
    }
}

#[test]
fn gram_record_vjp_matches_fd_and_backward_bitwise() {
    let mut rng = Rng::new(302);
    let (bx, by, l, d) = (2usize, 3usize, 4usize, 2usize);
    let x = rng.brownian_batch(bx, l, d, 0.4);
    let y = rng.brownian_batch(by, l, d, 0.4);
    let xb = PathBatch::uniform(&x, bx, l, d).unwrap();
    let yb = PathBatch::uniform(&y, by, l, d).unwrap();
    let opts = KernelOptions::default();
    let mut w = vec![0.0; bx * by];
    rng.fill_normal(&mut w);

    let plan = Plan::compile(OpSpec::Gram(opts), ShapeClass::uniform(d, l)).unwrap();
    let rec = plan.execute_pair(&xb, &yb).unwrap();
    assert_eq!(
        rec.values(),
        &pysiglib::kernel::try_gram(&xb, &yb, &opts).unwrap()[..]
    );
    let (gx, gy) = match rec.vjp(&w).unwrap() {
        Gradients::Pair(gx, gy) => (gx, gy),
        _ => panic!("gram vjp is pair-input"),
    };

    // Bit-for-bit identical to the pre-existing gram backward.
    let (wx, wy) = pysiglib::kernel::try_gram_vjp(&xb, &yb, &w, &opts).unwrap();
    assert_eq!(gx, wx);
    assert_eq!(gy, wy);

    // Central finite differences on F = Σ W ∘ Gram.
    let f = |xs: &[f64], ys: &[f64]| -> f64 {
        let xb = PathBatch::uniform(xs, bx, l, d).unwrap();
        let yb = PathBatch::uniform(ys, by, l, d).unwrap();
        let g = pysiglib::kernel::try_gram(&xb, &yb, &opts).unwrap();
        g.iter().zip(w.iter()).map(|(a, b)| a * b).sum()
    };
    let eps = 1e-6;
    for idx in 0..x.len() {
        let mut p = x.clone();
        p[idx] += eps;
        let fp = f(&p, &y);
        p[idx] -= 2.0 * eps;
        let fm = f(&p, &y);
        fd_check((fp - fm) / (2.0 * eps), gx[idx], "gram grad_x");
    }
    for idx in 0..y.len() {
        let mut p = y.clone();
        p[idx] += eps;
        let fp = f(&x, &p);
        p[idx] -= 2.0 * eps;
        let fm = f(&x, &p);
        fd_check((fp - fm) / (2.0 * eps), gy[idx], "gram grad_y");
    }
}

#[test]
fn mmd2_record_vjp_matches_fd_and_backward_bitwise() {
    let mut rng = Rng::new(303);
    let (bx, by, l, d) = (3usize, 3usize, 4usize, 2usize);
    let x = rng.brownian_batch(bx, l, d, 0.4);
    let y = rng.brownian_batch(by, l, d, 0.5);
    let xb = PathBatch::uniform(&x, bx, l, d).unwrap();
    let yb = PathBatch::uniform(&y, by, l, d).unwrap();
    let opts = KernelOptions::default();

    let plan = Plan::compile(OpSpec::Mmd2(opts), ShapeClass::uniform(d, l)).unwrap();
    let rec = plan.execute_pair(&xb, &yb).unwrap();
    let grad = match rec.vjp(&[1.0]).unwrap() {
        Gradients::Single(g) => g,
        _ => panic!("mmd2 vjp is single-gradient"),
    };

    // Bit-for-bit identical to the pre-existing entry point (value + grad).
    let (value, want) = pysiglib::kernel::try_mmd2_with_grad(&xb, &yb, &opts).unwrap();
    assert_eq!(rec.value(), value);
    assert_eq!(grad, want);
    // The record retains the three Gram matrices (forward intermediates).
    let (kxx, kxy, kyy) = rec.mmd_grams().expect("retained grams");
    assert_eq!((kxx.len(), kxy.len(), kyy.len()), (bx * bx, bx * by, by * by));

    // Central finite differences on MMD²(x, y) w.r.t. x.
    let f = |xs: &[f64]| -> f64 {
        let xb = PathBatch::uniform(xs, bx, l, d).unwrap();
        pysiglib::kernel::try_mmd2(&xb, &yb, &opts).unwrap()
    };
    let eps = 1e-5;
    for idx in 0..x.len() {
        let mut p = x.clone();
        p[idx] += eps;
        let fp = f(&p);
        p[idx] -= 2.0 * eps;
        let fm = f(&p);
        fd_check((fp - fm) / (2.0 * eps), grad[idx], "mmd2");
    }
}

/// The previously uncovered vjp-family member: unbiased MMD² through
/// `ExecutionRecord::vjp` against central finite differences, and
/// bit-for-bit against the `try_mmd2_unbiased_with_grad` entry point.
#[test]
fn mmd2_unbiased_record_vjp_matches_fd() {
    let mut rng = Rng::new(306);
    let (bx, by, l, d) = (3usize, 4usize, 4usize, 2usize);
    let x = rng.brownian_batch(bx, l, d, 0.4);
    let y = rng.brownian_batch(by, l, d, 0.5);
    let xb = PathBatch::uniform(&x, bx, l, d).unwrap();
    let yb = PathBatch::uniform(&y, by, l, d).unwrap();
    // Asymmetric dyadic orders: the discretised kernel is not symmetric in
    // its arguments, so this exercises the both-slots Kxx backward.
    let opts = KernelOptions::default().dyadic(1, 0);

    let plan = Plan::compile(OpSpec::Mmd2Unbiased(opts), ShapeClass::uniform(d, l)).unwrap();
    let rec = plan.execute_pair(&xb, &yb).unwrap();
    // Forward value matches the typed entry point.
    let want_value = pysiglib::kernel::try_mmd2_unbiased(&xb, &yb, &opts).unwrap();
    assert_eq!(rec.value(), want_value);
    let grad = match rec.vjp(&[1.0]).unwrap() {
        Gradients::Single(g) => g,
        _ => panic!("mmd2_unbiased vjp is single-gradient"),
    };
    // Bit-for-bit identical to the with-grad entry point.
    let (value, want) = pysiglib::kernel::try_mmd2_unbiased_with_grad(&xb, &yb, &opts).unwrap();
    assert_eq!(value, want_value);
    assert_eq!(grad, want);

    let f = |xs: &[f64]| -> f64 {
        let xb = PathBatch::uniform(xs, bx, l, d).unwrap();
        pysiglib::kernel::try_mmd2_unbiased(&xb, &yb, &opts).unwrap()
    };
    let eps = 1e-5;
    for idx in 0..x.len() {
        let mut p = x.clone();
        p[idx] += eps;
        let fp = f(&p);
        p[idx] -= 2.0 * eps;
        let fm = f(&p);
        fd_check((fp - fm) / (2.0 * eps), grad[idx], "mmd2_unbiased");
    }
}

/// Same check on a ragged batch: mixed path lengths through the U-statistic
/// vjp, gradients in x's own ragged layout.
#[test]
fn mmd2_unbiased_record_vjp_matches_fd_ragged() {
    let mut rng = Rng::new(307);
    let d = 2;
    let xl = [3usize, 5, 4];
    let yl = [4usize, 2, 6];
    let (mut xdata, mut ydata) = (Vec::new(), Vec::new());
    for &l in &xl {
        xdata.extend(rng.brownian_path(l, d, 0.4));
    }
    for &l in &yl {
        ydata.extend(rng.brownian_path(l, d, 0.5));
    }
    let xb = PathBatch::ragged(&xdata, &xl, d).unwrap();
    let yb = PathBatch::ragged(&ydata, &yl, d).unwrap();
    let opts = KernelOptions::default();

    let plan = Plan::compile(OpSpec::Mmd2Unbiased(opts), ShapeClass::for_pair(&xb, &yb)).unwrap();
    let rec = plan.execute_pair(&xb, &yb).unwrap();
    let grad = match rec.vjp(&[1.0]).unwrap() {
        Gradients::Single(g) => g,
        _ => panic!("mmd2_unbiased vjp is single-gradient"),
    };
    assert_eq!(grad.len(), xb.total_points() * d);
    let f = |xs: &[f64]| -> f64 {
        let xb = PathBatch::ragged(xs, &xl, d).unwrap();
        pysiglib::kernel::try_mmd2_unbiased(&xb, &yb, &opts).unwrap()
    };
    let eps = 1e-5;
    for idx in 0..xdata.len() {
        let mut p = xdata.clone();
        p[idx] += eps;
        let fp = f(&p);
        p[idx] -= 2.0 * eps;
        let fm = f(&p);
        fd_check((fp - fm) / (2.0 * eps), grad[idx], "mmd2_unbiased ragged");
    }
    // Batches below the U-statistic minimum error cleanly.
    let one = PathBatch::ragged(&xdata[..3 * d], &[3], d).unwrap();
    assert!(matches!(
        pysiglib::kernel::try_mmd2_unbiased(&one, &yb, &opts),
        Err(pysiglib::SigError::InsufficientBatch { need: 2, .. })
    ));
}

/// Plan-cached execution is bit-identical to one-shot execution, on uniform
/// and ragged batches, across repeated warm-cache runs.
#[test]
fn cached_plans_bitmatch_one_shot_execution() {
    let mut rng = Rng::new(304);
    let session = Session::new();
    let d = 2;
    for trial in 0..6 {
        let depth = 2 + trial % 3;
        let opts = SigOptions::new(depth);
        // Alternate uniform / ragged shapes.
        let lengths: Vec<usize> = if trial % 2 == 0 {
            vec![6; 4]
        } else {
            vec![3 + trial, 1, 7, 2]
        };
        let mut data = Vec::new();
        for &l in &lengths {
            data.extend(rng.brownian_path(l, d, 0.4));
        }
        let pb = PathBatch::ragged(&data, &lengths, d).unwrap();
        let one_shot = pysiglib::sig::try_batch_signature(&pb, &opts).unwrap();
        // Twice through the session: the second lookup is a warm cache hit,
        // and both executions are identical to one-shot.
        for run in 0..2 {
            let plan = session
                .plan(OpSpec::Sig(opts), ShapeClass::for_batch(&pb))
                .unwrap();
            let rec = plan.execute(&pb).unwrap();
            assert_eq!(rec.values(), &one_shot[..], "trial {trial} run {run}");
        }
    }
    let stats = session.cache_stats();
    assert!(stats.hits > 0, "repeated shape classes must hit: {stats:?}");

    // Same property through the kernel/Gram route.
    let xl = [4usize, 2, 6];
    let yl = [3usize, 5, 2];
    let (mut xdata, mut ydata) = (Vec::new(), Vec::new());
    for &l in &xl {
        xdata.extend(rng.brownian_path(l, d, 0.4));
    }
    for &l in &yl {
        ydata.extend(rng.brownian_path(l, d, 0.4));
    }
    let xb = PathBatch::ragged(&xdata, &xl, d).unwrap();
    let yb = PathBatch::ragged(&ydata, &yl, d).unwrap();
    let kopts = KernelOptions::default().dyadic(1, 1);
    let one_shot = pysiglib::kernel::try_gram(&xb, &yb, &kopts).unwrap();
    let plan = session
        .plan(OpSpec::Gram(kopts), ShapeClass::for_pair(&xb, &yb))
        .unwrap();
    for _ in 0..2 {
        let rec = plan.execute_pair(&xb, &yb).unwrap();
        assert_eq!(rec.values(), &one_shot[..]);
    }
}

/// The steady state allocates nothing: executing the same plan twice on
/// same-shape inputs leaves the workspace arena's allocation counter flat.
#[test]
fn warm_plans_allocate_nothing_for_sig_and_kernel_and_vjp_inputs() {
    let mut rng = Rng::new(305);
    let (b, l, d) = (5usize, 10usize, 3usize);
    let data = rng.brownian_batch(b, l, d, 0.4);
    let pb = PathBatch::uniform(&data, b, l, d).unwrap();

    let plan = Plan::compile(OpSpec::Sig(SigOptions::new(3)), ShapeClass::uniform(d, l)).unwrap();
    drop(plan.execute(&pb).unwrap());
    let warm = plan.allocations();
    drop(plan.execute(&pb).unwrap());
    drop(plan.execute(&pb).unwrap());
    assert_eq!(plan.allocations(), warm, "sig plan steady state");

    let y = rng.brownian_batch(b, l, d, 0.4);
    let yb = PathBatch::uniform(&y, b, l, d).unwrap();
    let kplan = Plan::compile(
        OpSpec::SigKernel(KernelOptions::default().dyadic(1, 1)),
        ShapeClass::uniform(d, l),
    )
    .unwrap();
    drop(kplan.execute_pair(&pb, &yb).unwrap());
    let warm = kplan.allocations();
    drop(kplan.execute_pair(&pb, &yb).unwrap());
    assert_eq!(kplan.allocations(), warm, "kernel plan steady state");
}

/// The lane-batched Gram producers reach the same zero-allocation steady
/// state for every lane width: worker scratch is checked out of the arena
/// at per-batch maxima the dispatcher's per-row `ensure` never exceeds,
/// and every width produces the identical values while doing it.
#[test]
fn warm_gram_and_mmd2_plans_allocate_nothing_at_any_lane_width() {
    let mut rng = Rng::new(306);
    let (b, l, d) = (12usize, 8usize, 2usize);
    let x = rng.brownian_batch(b, l, d, 0.4);
    let y = rng.brownian_batch(b, l, d, 0.4);
    let xb = PathBatch::uniform(&x, b, l, d).unwrap();
    let yb = PathBatch::uniform(&y, b, l, d).unwrap();
    // Options chosen to drift-proof the shared scratch-sizing arithmetic:
    // dyadic_y exercises the interleaved-row formula, LeadLag the base
    // block and transformed Δ dims.
    for opts in [
        KernelOptions::default().dyadic(1, 0),
        KernelOptions::default().dyadic(0, 2),
        KernelOptions::default().transform(pysiglib::transforms::Transform::LeadLag),
    ] {
        let mut reference: Option<Vec<f64>> = None;
        for width in [0usize, 4, 8] {
            let plan = Plan::compile_forward(OpSpec::Gram(opts), ShapeClass::uniform(d, l))
                .unwrap()
                .with_lane_width(width);
            let r1 = plan.execute_pair(&xb, &yb).unwrap();
            let first = r1.values().to_vec();
            drop(r1); // buffers return to the arena before the warm measurement
            let warm = plan.allocations();
            let rec = plan.execute_pair(&xb, &yb).unwrap();
            assert_eq!(rec.values(), &first[..], "repeat must be bit-identical");
            drop(rec);
            assert_eq!(
                plan.allocations(),
                warm,
                "gram steady state (width={width}, opts={opts:?})"
            );
            match &reference {
                None => reference = Some(first),
                Some(r) => assert_eq!(&first, r, "width={width} must match scalar"),
            }
        }
    }
    let plan = Plan::compile_forward(
        OpSpec::Mmd2(KernelOptions::default()),
        ShapeClass::uniform(d, l),
    )
    .unwrap();
    drop(plan.execute_pair(&xb, &yb).unwrap());
    let warm = plan.allocations();
    drop(plan.execute_pair(&xb, &yb).unwrap());
    assert_eq!(plan.allocations(), warm, "mmd2 steady state");
}
