//! Property tests for corpus snapshot persistence — the acceptance bar for
//! crash recovery: a registry restored from a snapshot is **bit-identical**
//! to the live registry it was taken from, on every query path (MMD² and
//! Gram, exact and low-rank, Nyström and random-signature features, uniform
//! and ragged corpora), and answers those queries warm (zero cold rebuilds).
//! Hostile snapshot files — truncations, flipped bytes, wrong magic or
//! version — must produce the typed [`SigError::SnapshotCorrupt`] (or a
//! clean derived-state drop) and never a panic.

use pysiglib::corpus::CorpusRegistry;
use pysiglib::kernel::{KernelOptions, LowRankSpec};
use pysiglib::util::rng::Rng;
use pysiglib::{PathBatch, SigError};

/// Fresh per-test scratch directory (removed by each test on success).
fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("pysiglib-props-persist-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Build a ragged batch's backing store.
fn ragged(rng: &mut Rng, lens: &[usize], d: usize) -> (Vec<f64>, Vec<usize>) {
    let mut data = Vec::new();
    for &l in lens {
        data.extend(rng.brownian_path(l, d, 0.35));
    }
    (data, lens.to_vec())
}

/// Register `corpus`, warm both query families, snapshot, restore — and
/// require the restored registry to answer both queries bit-identically
/// and warm (its caches came from the file, not a rebuild).
fn check_restore_is_bitwise(
    d: usize,
    corpus: (&[f64], &[usize]),
    query: (&[f64], &[usize]),
    spec: Option<&LowRankSpec>,
    label: &str,
) {
    let opts = KernelOptions::default();
    let cb = PathBatch::ragged(corpus.0, corpus.1, d).unwrap();
    let qb = PathBatch::ragged(query.0, query.1, d).unwrap();

    let live = CorpusRegistry::new();
    let id = live.register(&cb).unwrap();
    let live_mmd = live.mmd2_query(id, &qb, &opts, spec).unwrap();
    let live_gram = live.gram_query(id, &qb, &opts, spec).unwrap();

    let dir = scratch(label);
    let file = dir.join("corpus.snapshot");
    assert_eq!(live.snapshot_to(&file).unwrap(), 1, "{label}");

    let restored = CorpusRegistry::restore_from(&file).unwrap();
    let rid = restored.ids().pop().unwrap();
    assert_eq!(rid, id, "{label}: restore must preserve corpus ids");
    let rest_mmd = restored.mmd2_query(rid, &qb, &opts, spec).unwrap();
    let rest_gram = restored.gram_query(rid, &qb, &opts, spec).unwrap();

    assert!(
        live_mmd.to_bits() == rest_mmd.to_bits(),
        "{label}: mmd2 {live_mmd:?} vs {rest_mmd:?}"
    );
    assert_eq!(live_gram.len(), rest_gram.len(), "{label}");
    for (i, (a, b)) in live_gram.iter().zip(rest_gram.iter()).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{label}: gram[{i}] {a:?} vs {b:?}");
    }
    let stats = restored.stats();
    assert_eq!(stats.cold_builds, 0, "{label}: restored queries must be warm");
    assert!(stats.warm_hits >= 2, "{label}: stats {stats:?}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn restore_is_bitwise_exact_uniform() {
    let mut rng = Rng::new(910);
    let d = 3;
    let (c, lc) = ragged(&mut rng, &[12; 8], d);
    let (q, lq) = ragged(&mut rng, &[12; 4], d);
    check_restore_is_bitwise(d, (&c, &lc), (&q, &lq), None, "exact-uniform");
}

#[test]
fn restore_is_bitwise_exact_ragged() {
    let mut rng = Rng::new(911);
    let d = 2;
    let (c, lc) = ragged(&mut rng, &[5, 13, 8, 21, 3, 9], d);
    let (q, lq) = ragged(&mut rng, &[7, 11, 4], d);
    check_restore_is_bitwise(d, (&c, &lc), (&q, &lq), None, "exact-ragged");
}

#[test]
fn restore_is_bitwise_nystrom() {
    let mut rng = Rng::new(912);
    let d = 3;
    let (c, lc) = ragged(&mut rng, &[10, 6, 14, 10, 8, 10, 12, 9], d);
    let (q, lq) = ragged(&mut rng, &[9, 12, 6], d);
    let spec = LowRankSpec::nystrom(6, 41);
    check_restore_is_bitwise(d, (&c, &lc), (&q, &lq), Some(&spec), "nystrom");
}

#[test]
fn restore_is_bitwise_random_sig() {
    let mut rng = Rng::new(913);
    let d = 2;
    let (c, lc) = ragged(&mut rng, &[8, 12, 6, 10, 9, 7], d);
    let (q, lq) = ragged(&mut rng, &[8, 10], d);
    let spec = LowRankSpec::random_sig(8, 3, 57);
    check_restore_is_bitwise(d, (&c, &lc), (&q, &lq), Some(&spec), "random-sig");
}

#[test]
fn restore_carries_every_registered_corpus() {
    let mut rng = Rng::new(914);
    let d = 2;
    let opts = KernelOptions::default();
    let (a, la) = ragged(&mut rng, &[9, 7, 11], d);
    let (b, lb) = ragged(&mut rng, &[6, 6, 6, 6], d);
    let (q, lq) = ragged(&mut rng, &[8, 5], d);
    let qb = PathBatch::ragged(&q, &lq, d).unwrap();

    let live = CorpusRegistry::new();
    let ida = live.register(&PathBatch::ragged(&a, &la, d).unwrap()).unwrap();
    let idb = live.register(&PathBatch::ragged(&b, &lb, d).unwrap()).unwrap();
    let ma = live.mmd2_query(ida, &qb, &opts, None).unwrap();
    let mb = live.mmd2_query(idb, &qb, &opts, None).unwrap();

    let dir = scratch("multi");
    let file = dir.join("corpus.snapshot");
    assert_eq!(live.snapshot_to(&file).unwrap(), 2);
    let restored = CorpusRegistry::restore_from(&file).unwrap();
    assert_eq!(restored.ids(), vec![ida, idb]);
    let ra = restored.mmd2_query(ida, &qb, &opts, None).unwrap();
    let rb = restored.mmd2_query(idb, &qb, &opts, None).unwrap();
    assert!(ma.to_bits() == ra.to_bits() && mb.to_bits() == rb.to_bits());
    assert_eq!(restored.stats().cold_builds, 0);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Hostile inputs: every corruption is a typed error or a clean drop.

fn u64_at(bytes: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap())
}

/// Walk the snapshot's section table: (tag, body_start, body_len).
fn sections(bytes: &[u8]) -> Vec<(u64, usize, usize)> {
    let count = u64_at(bytes, 16) as usize;
    let mut out = Vec::new();
    let mut at = 24;
    for _ in 0..count {
        let tag = u64_at(bytes, at);
        let len = u64_at(bytes, at + 8) as usize;
        out.push((tag, at + 24, len));
        at += 24 + len;
    }
    assert_eq!(at, bytes.len(), "section table must span the file");
    out
}

/// A warmed single-corpus snapshot (exact + Nyström caches) plus the query
/// it was warmed with and the live answer, for corruption experiments.
fn warm_snapshot_bytes(dir: &std::path::Path) -> (Vec<u8>, Vec<f64>, Vec<usize>, f64) {
    let mut rng = Rng::new(915);
    let d = 2;
    let (c, lc) = ragged(&mut rng, &[8, 10, 6, 9], d);
    let (q, lq) = ragged(&mut rng, &[7, 5], d);
    let cb = PathBatch::ragged(&c, &lc, d).unwrap();
    let qb = PathBatch::ragged(&q, &lq, d).unwrap();
    let opts = KernelOptions::default();
    let spec = LowRankSpec::nystrom(4, 23);
    let live = CorpusRegistry::new();
    let id = live.register(&cb).unwrap();
    let mmd = live.mmd2_query(id, &qb, &opts, None).unwrap();
    live.mmd2_query(id, &qb, &opts, Some(&spec)).unwrap();
    let file = dir.join("corpus.snapshot");
    live.snapshot_to(&file).unwrap();
    (std::fs::read(&file).unwrap(), q, lq, mmd)
}

#[test]
fn truncated_snapshots_are_typed_errors() {
    let dir = scratch("truncate");
    let (bytes, ..) = warm_snapshot_bytes(&dir);
    let file = dir.join("cut.snapshot");
    for cut in [0, 7, 8, 23, bytes.len() / 2, bytes.len() - 1] {
        std::fs::write(&file, &bytes[..cut]).unwrap();
        match CorpusRegistry::restore_from(&file) {
            Err(SigError::SnapshotCorrupt(_)) => {}
            other => panic!("cut at {cut}: expected SnapshotCorrupt, got {other:?}"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wrong_magic_and_version_are_typed_errors() {
    let dir = scratch("header");
    let (bytes, ..) = warm_snapshot_bytes(&dir);
    let file = dir.join("bad.snapshot");
    let mut magic = bytes.clone();
    magic[0] ^= 0xff;
    std::fs::write(&file, &magic).unwrap();
    match CorpusRegistry::restore_from(&file) {
        Err(SigError::SnapshotCorrupt(msg)) => assert!(msg.contains("magic"), "{msg}"),
        other => panic!("expected SnapshotCorrupt, got {other:?}"),
    }
    let mut version = bytes.clone();
    version[8] = 99;
    std::fs::write(&file, &version).unwrap();
    match CorpusRegistry::restore_from(&file) {
        Err(SigError::SnapshotCorrupt(msg)) => assert!(msg.contains("version"), "{msg}"),
        other => panic!("expected SnapshotCorrupt, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn a_flipped_byte_in_each_section_drops_derived_state_but_fails_paths() {
    let dir = scratch("flip");
    let (bytes, q, lq, live_mmd) = warm_snapshot_bytes(&dir);
    let qb = PathBatch::ragged(&q, &lq, 2).unwrap();
    let opts = KernelOptions::default();
    let file = dir.join("flipped.snapshot");
    let secs = sections(&bytes);
    assert!(secs.iter().any(|&(tag, ..)| tag == 2), "exact section present");
    assert!(secs.iter().any(|&(tag, ..)| tag == 3), "low-rank section present");
    for &(tag, start, len) in &secs {
        let mut b = bytes.clone();
        b[start + len / 2] ^= 0x20;
        std::fs::write(&file, &b).unwrap();
        match (tag, CorpusRegistry::restore_from(&file)) {
            // Tag 1 = paths: mandatory, a checksum failure fails the load.
            (1, Err(SigError::SnapshotCorrupt(msg))) => {
                assert!(msg.contains("checksum"), "{msg}")
            }
            // Tags 2-3 = derived caches: dropped, rebuilt lazily — and the
            // rebuilt answer still matches the live registry bit-for-bit.
            (2 | 3, Ok(restored)) => {
                let rid = restored.ids().pop().unwrap();
                let m = restored.mmd2_query(rid, &qb, &opts, None).unwrap();
                assert!(m.to_bits() == live_mmd.to_bits(), "section {tag}");
            }
            (tag, other) => panic!("section {tag}: unexpected outcome {other:?}"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_snapshot_is_a_backend_error_not_a_panic() {
    let dir = scratch("missing");
    let gone = dir.join("never-written.snapshot");
    match CorpusRegistry::restore_from(&gone) {
        Err(SigError::Backend(_)) => {}
        other => panic!("expected Backend error, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
