//! CLI smoke tests through the library entry point (no subprocess spawn).

#[test]
fn help_exits_zero() {
    assert_eq!(pysiglib::cli::cli_main(&["help".into()]), 0);
}

#[test]
fn unknown_command_exits_nonzero() {
    assert_ne!(pysiglib::cli::cli_main(&["frobnicate".into()]), 0);
}

#[test]
fn sig_command_runs() {
    let args: Vec<String> = ["sig", "--batch", "4", "--len", "16", "--dim", "2", "--depth", "3"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn kernel_command_runs_with_blocked_solver() {
    let args: Vec<String> = [
        "kernel", "--batch", "4", "--len", "24", "--dim", "2", "--solver", "blocked",
        "--transform", "leadlag",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn grad_command_runs() {
    let args: Vec<String> = ["grad", "--batch", "2", "--len", "12", "--dim", "2"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn logsig_command_runs() {
    let args: Vec<String> = ["logsig", "--batch", "2", "--len", "10", "--dim", "2", "--depth", "3"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn sig_command_runs_ragged() {
    let args: Vec<String> = [
        "sig", "--batch", "6", "--len", "16", "--dim", "2", "--depth", "3", "--ragged",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn kernel_command_runs_ragged() {
    let args: Vec<String> = [
        "kernel", "--batch", "4", "--len", "12", "--dim", "2", "--ragged",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn kernel_command_runs_lifted() {
    // The static-kernel lift is reachable from the CLI (RBF and linear).
    let args: Vec<String> = [
        "kernel", "--batch", "3", "--len", "10", "--dim", "2", "--lifted", "rbf", "--sigma",
        "0.8",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
    let args: Vec<String> = [
        "kernel", "--batch", "3", "--len", "10", "--dim", "2", "--lifted", "linear",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
    // Unknown static kernel is a usage error.
    let args: Vec<String> = ["kernel", "--lifted", "cubic"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_ne!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn mmd_command_runs_exact_and_lowrank() {
    let base = ["mmd", "--batch", "6", "--len", "10", "--dim", "2"];
    let exact: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    assert_eq!(pysiglib::cli::cli_main(&exact), 0);
    let mut nystrom: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    nystrom.extend(["--landmarks".to_string(), "3".to_string()]);
    assert_eq!(pysiglib::cli::cli_main(&nystrom), 0);
    let mut randsig: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    randsig.extend(
        ["--rank", "8", "--features", "randsig", "--depth", "3", "--unbiased"]
            .iter()
            .map(|s| s.to_string()),
    );
    assert_eq!(pysiglib::cli::cli_main(&randsig), 0);
    // Unknown feature family is a usage error.
    let mut bad: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    bad.extend(
        ["--rank", "4", "--features", "magic"]
            .iter()
            .map(|s| s.to_string()),
    );
    assert_ne!(pysiglib::cli::cli_main(&bad), 0);
    // --landmarks means Nyström; combining it with randsig is a usage error.
    let mut conflict: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    conflict.extend(
        ["--landmarks", "3", "--features", "randsig"]
            .iter()
            .map(|s| s.to_string()),
    );
    assert_ne!(pysiglib::cli::cli_main(&conflict), 0);
}

#[test]
fn corpus_command_runs_local_demo_and_validates_usage() {
    // In-process lifecycle demo (register → cold/warm query → append →
    // re-query), exact and low-rank.
    let base = [
        "corpus", "mmd", "--batch", "8", "--len", "8", "--dim", "2", "--queries", "3",
        "--append", "2",
    ];
    let exact: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    assert_eq!(pysiglib::cli::cli_main(&exact), 0);
    let mut lowrank: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    lowrank.extend(["--rank".to_string(), "4".to_string()]);
    assert_eq!(pysiglib::cli::cli_main(&lowrank), 0);
    // Lane/tile scheduling knobs: every width is bit-identical, so each
    // demo run must succeed (including forced-scalar).
    for lanes in ["0", "4", "8"] {
        let mut with_lanes: Vec<String> = base.iter().map(|s| s.to_string()).collect();
        with_lanes.extend(["--lanes".to_string(), lanes.to_string()]);
        with_lanes.extend(["--tile".to_string(), "4".to_string()]);
        assert_eq!(pysiglib::cli::cli_main(&with_lanes), 0, "lanes={lanes}");
    }
    // register/append need a server.
    let args: Vec<String> = ["corpus", "register"].iter().map(|s| s.to_string()).collect();
    assert_ne!(pysiglib::cli::cli_main(&args), 0);
    // Unknown subcommand is a usage error too.
    let args: Vec<String> = ["corpus", "frobnicate"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_ne!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn selfcheck_passes() {
    assert_eq!(pysiglib::cli::cli_main(&["selfcheck".into()]), 0);
}
