//! CLI smoke tests through the library entry point (no subprocess spawn).

#[test]
fn help_exits_zero() {
    assert_eq!(pysiglib::cli::cli_main(&["help".into()]), 0);
}

#[test]
fn unknown_command_exits_nonzero() {
    assert_ne!(pysiglib::cli::cli_main(&["frobnicate".into()]), 0);
}

#[test]
fn sig_command_runs() {
    let args: Vec<String> = ["sig", "--batch", "4", "--len", "16", "--dim", "2", "--depth", "3"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn kernel_command_runs_with_blocked_solver() {
    let args: Vec<String> = [
        "kernel", "--batch", "4", "--len", "24", "--dim", "2", "--solver", "blocked",
        "--transform", "leadlag",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn grad_command_runs() {
    let args: Vec<String> = ["grad", "--batch", "2", "--len", "12", "--dim", "2"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn logsig_command_runs() {
    let args: Vec<String> = ["logsig", "--batch", "2", "--len", "10", "--dim", "2", "--depth", "3"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn sig_command_runs_ragged() {
    let args: Vec<String> = [
        "sig", "--batch", "6", "--len", "16", "--dim", "2", "--depth", "3", "--ragged",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn kernel_command_runs_ragged() {
    let args: Vec<String> = [
        "kernel", "--batch", "4", "--len", "12", "--dim", "2", "--ragged",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(pysiglib::cli::cli_main(&args), 0);
}

#[test]
fn selfcheck_passes() {
    assert_eq!(pysiglib::cli::cli_main(&["selfcheck".into()]), 0);
}
