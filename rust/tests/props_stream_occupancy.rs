//! The streaming tentpole's occupancy acceptance: a steady-state
//! `extend_path` solves **only** the Goursat border strip — cell counts
//! scale with `L_new·L`, not `L²`. This lives in its own test binary (one
//! `#[test]`) because `border_cells_solved()` and the lane tile counter
//! are process-global; sharing a process with the other streaming
//! property tests would make the exact deltas racy.

use pysiglib::corpus::CorpusRegistry;
use pysiglib::kernel::{border_cells_solved, lanes, KernelOptions};
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

#[test]
fn steady_state_extend_solves_exactly_the_border_strip() {
    let (n, l, d, warm, add) = (3usize, 32usize, 2usize, 2usize, 4usize);
    let opts = KernelOptions::default();
    let mut rng = Rng::new(940);
    let corpus = rng.brownian_batch(n, l, d, 0.3);
    let ext = rng.brownian_batch(1, warm + add, d, 0.3);
    let q = rng.brownian_batch(1, 6, d, 0.35);
    let qb = PathBatch::uniform(&q, 1, 6, d).unwrap();

    let reg = CorpusRegistry::new();
    let id = reg.register(&PathBatch::uniform(&corpus, n, l, d).unwrap()).unwrap();
    reg.mmd2_query(id, &qb, &opts, None).unwrap();

    // Warm-up extend: no borders are retained yet, so every pair touching
    // path 0 pays a one-off full O(L²) retaining solve.
    let c0 = border_cells_solved();
    reg.extend_path(id, 0, &ext[..warm * d]).unwrap();
    let warm_cells = border_cells_solved() - c0;

    // Steady-state extend: borders retained, only strips are swept — and
    // no lane tiles execute (the strip path is scalar).
    let t0 = lanes::stats().tiles_executed;
    let c1 = border_cells_solved();
    reg.extend_path(id, 0, &ext[warm * d..]).unwrap();
    let strip_cells = border_cells_solved() - c1;
    assert_eq!(lanes::stats().tiles_executed, t0, "steady extend ran tiles");

    // Exact strip accounting for the Plain transform at λ = 0, with
    // l_old = L + warm after the warm-up:
    //   diagonal pair  — column strip over the old rows, then the new rows
    //                    at full width: (l_old−1)·add + add·(l_old+add−1)
    //   each partner j — row strip (0,j) plus column strip (j,0):
    //                    2·add·(L−1)
    let l_old = l + warm;
    let expected = ((l_old - 1) * add + add * (l_old + add - 1) + 2 * add * (l - 1) * (n - 1)) as u64;
    assert_eq!(strip_cells, expected, "steady extend swept more than the strip");

    // The warm-up's retaining solves are quadratic in L; the steady strip
    // is linear in L (times L_new) — the O(L_new·L) vs O(L²) claim.
    let warm_floor = ((l_old - 1) * (l_old - 1) + 2 * (n - 1) * (l_old - 1) * (l - 1)) as u64;
    assert!(warm_cells >= warm_floor, "warm-up {warm_cells} below {warm_floor}");
    assert!(
        4 * strip_cells < warm_cells,
        "strip {strip_cells} not clearly sublinear vs warm-up {warm_cells}"
    );

    // And the streamed state still serves: the re-query is warm and equals
    // a from-scratch registration bitwise.
    let v = reg.mmd2_query(id, &qb, &opts, None).unwrap();
    assert_eq!(reg.stats().cold_builds, 1);
    let mut grown = corpus.clone();
    grown.splice(l * d..l * d, ext.iter().copied());
    let mut glens = vec![l; n];
    glens[0] = l + warm + add;
    let scratch = CorpusRegistry::new();
    let sid = scratch.register(&PathBatch::ragged(&grown, &glens, d).unwrap()).unwrap();
    let sv = scratch.mmd2_query(sid, &qb, &opts, None).unwrap();
    assert!(v.to_bits() == sv.to_bits(), "{v:?} vs {sv:?}");
}
