//! Property tests for the corpus subsystem — the acceptance bar for
//! incremental serving: append-then-query is **bit-identical** to
//! registering the combined corpus from scratch (uniform and ragged
//! corpora, exact and low-rank paths, Nyström and random-signature
//! features). Every assertion is exact `==` on `f64`s: values must not
//! depend on scheduling, tiling, thread count or append history.
//!
//! The thread-count property used to live in its own binary
//! (`props_corpus_threads.rs`) because it mutated `PYSIGLIB_THREADS` via
//! `std::env::set_var`, racing sibling tests' `getenv` calls at the libc
//! level. Env knobs are now read once per process (`config::env`) and the
//! sweep uses the explicit `set_thread_override` API, so the property is
//! an ordinary test here again.

use std::sync::Arc;

use pysiglib::corpus::{CorpusRegistry, TileScheduler};
use pysiglib::engine::{OpSpec, Plan, PlanCache, ShapeClass};
use pysiglib::kernel::{try_gram, KernelOptions, LowRankSpec};
use pysiglib::transforms::Transform;
use pysiglib::util::pool::set_thread_override;
use pysiglib::util::rng::Rng;
use pysiglib::{PathBatch, SigError};

/// Build a ragged batch's backing store.
fn ragged(rng: &mut Rng, lens: &[usize], d: usize) -> (Vec<f64>, Vec<usize>) {
    let mut data = Vec::new();
    for &l in lens {
        data.extend(rng.brownian_path(l, d, 0.35));
    }
    (data, lens.to_vec())
}

/// Drive one scenario: register `part1`, warm every query family, append
/// `part2`, query again — against a from-scratch registration of the
/// combined corpus. Checks MMD² and Gram, exact and (when `spec` is set)
/// low-rank, for exact bitwise equality.
fn check_append_matches_scratch(
    d: usize,
    part1: (&[f64], &[usize]),
    part2: (&[f64], &[usize]),
    query: (&[f64], &[usize]),
    spec: Option<&LowRankSpec>,
    label: &str,
) {
    let opts = KernelOptions::default();
    let p1 = PathBatch::ragged(part1.0, part1.1, d).unwrap();
    let p2 = PathBatch::ragged(part2.0, part2.1, d).unwrap();
    let qb = PathBatch::ragged(query.0, query.1, d).unwrap();
    let mut combined = part1.0.to_vec();
    combined.extend_from_slice(part2.0);
    let mut combined_lens = part1.1.to_vec();
    combined_lens.extend_from_slice(part2.1);
    let cb = PathBatch::ragged(&combined, &combined_lens, d).unwrap();

    // Incremental: register part1, WARM the caches, then append.
    let inc = CorpusRegistry::new();
    let id = inc.register(&p1).unwrap();
    inc.mmd2_query(id, &qb, &opts, spec).unwrap();
    inc.gram_query(id, &qb, &opts, spec).unwrap();
    let total = inc.append(id, &p2).unwrap();
    assert_eq!(total, part1.1.len() + part2.1.len(), "{label}");
    let inc_mmd = inc.mmd2_query(id, &qb, &opts, spec).unwrap();
    let inc_gram = inc.gram_query(id, &qb, &opts, spec).unwrap();
    // The appended queries must be warm (state extended, not rebuilt):
    // only the single cold build of the first pre-append query remains.
    assert_eq!(inc.stats().cold_builds, 1, "{label}");

    // From scratch: register the combined corpus, query cold.
    let scratch = CorpusRegistry::new();
    let sid = scratch.register(&cb).unwrap();
    let scr_mmd = scratch.mmd2_query(sid, &qb, &opts, spec).unwrap();
    let scr_gram = scratch.gram_query(sid, &qb, &opts, spec).unwrap();

    assert!(
        inc_mmd.to_bits() == scr_mmd.to_bits(),
        "{label}: mmd2 {inc_mmd:?} vs {scr_mmd:?}"
    );
    assert_eq!(inc_gram.len(), scr_gram.len(), "{label}");
    for (i, (a, b)) in inc_gram.iter().zip(scr_gram.iter()).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{label}: gram[{i}] {a:?} vs {b:?}"
        );
    }
}

#[test]
fn append_then_query_bit_identical_exact_uniform() {
    let mut rng = Rng::new(800);
    let d = 3;
    let (p1, l1) = ragged(&mut rng, &[8; 6], d);
    let (p2, l2) = ragged(&mut rng, &[8; 3], d);
    let (q, lq) = ragged(&mut rng, &[8; 4], d);
    check_append_matches_scratch(d, (&p1, &l1), (&p2, &l2), (&q, &lq), None, "exact uniform");
}

#[test]
fn append_then_query_bit_identical_exact_ragged() {
    let mut rng = Rng::new(801);
    let d = 2;
    let (p1, l1) = ragged(&mut rng, &[5, 9, 2, 7, 4, 6], d);
    let (p2, l2) = ragged(&mut rng, &[8, 3, 10], d);
    let (q, lq) = ragged(&mut rng, &[6, 1, 7], d);
    check_append_matches_scratch(d, (&p1, &l1), (&p2, &l2), (&q, &lq), None, "exact ragged");
}

#[test]
fn append_then_query_bit_identical_nystrom() {
    let mut rng = Rng::new(802);
    let d = 2;
    // The initial corpus covers the rank budget (6 ≥ 4), so the landmark
    // pool — and with it the seeded landmark draw — is append-invariant.
    let spec = LowRankSpec::nystrom(4, 11);
    let (p1, l1) = ragged(&mut rng, &[7; 6], d);
    let (p2, l2) = ragged(&mut rng, &[7; 3], d);
    let (q, lq) = ragged(&mut rng, &[7; 4], d);
    check_append_matches_scratch(
        d,
        (&p1, &l1),
        (&p2, &l2),
        (&q, &lq),
        Some(&spec),
        "nystrom uniform",
    );
    let (p1, l1) = ragged(&mut rng, &[4, 8, 5, 9, 3, 6], d);
    let (p2, l2) = ragged(&mut rng, &[7, 2, 8, 5], d);
    let (q, lq) = ragged(&mut rng, &[5, 8], d);
    check_append_matches_scratch(
        d,
        (&p1, &l1),
        (&p2, &l2),
        (&q, &lq),
        Some(&spec),
        "nystrom ragged",
    );
}

#[test]
fn append_then_query_bit_identical_randsig() {
    let mut rng = Rng::new(803);
    let d = 2;
    // The random-signature sketch depends only on (seed, shape): the map is
    // append-invariant regardless of corpus size.
    let spec = LowRankSpec::random_sig(8, 3, 13);
    let (p1, l1) = ragged(&mut rng, &[6; 5], d);
    let (p2, l2) = ragged(&mut rng, &[6; 4], d);
    let (q, lq) = ragged(&mut rng, &[6; 3], d);
    check_append_matches_scratch(
        d,
        (&p1, &l1),
        (&p2, &l2),
        (&q, &lq),
        Some(&spec),
        "randsig uniform",
    );
    let (p1, l1) = ragged(&mut rng, &[3, 7, 5, 8], d);
    let (p2, l2) = ragged(&mut rng, &[6, 2], d);
    let (q, lq) = ragged(&mut rng, &[4, 6, 5], d);
    check_append_matches_scratch(
        d,
        (&p1, &l1),
        (&p2, &l2),
        (&q, &lq),
        Some(&spec),
        "randsig ragged",
    );
}

/// When the initial corpus is *smaller* than the rank budget, the landmark
/// pool grows on append; the registry rebuilds the map exactly as a
/// from-scratch registration would — still value-identical, just not
/// incremental.
#[test]
fn append_below_rank_budget_rebuilds_and_still_matches_scratch() {
    let mut rng = Rng::new(804);
    let d = 2;
    let spec = LowRankSpec::nystrom(5, 17);
    let (p1, l1) = ragged(&mut rng, &[6, 4, 7], d); // 3 < rank 5
    let (p2, l2) = ragged(&mut rng, &[5, 8, 6, 4], d);
    let (q, lq) = ragged(&mut rng, &[5, 6], d);
    let opts = KernelOptions::default();
    let p1b = PathBatch::ragged(&p1, &l1, d).unwrap();
    let p2b = PathBatch::ragged(&p2, &l2, d).unwrap();
    let qb = PathBatch::ragged(&q, &lq, d).unwrap();
    let inc = CorpusRegistry::new();
    let id = inc.register(&p1b).unwrap();
    inc.mmd2_query(id, &qb, &opts, Some(&spec)).unwrap();
    inc.append(id, &p2b).unwrap();
    let inc_mmd = inc.mmd2_query(id, &qb, &opts, Some(&spec)).unwrap();
    let mut combined = p1.clone();
    combined.extend_from_slice(&p2);
    let mut lens = l1.clone();
    lens.extend_from_slice(&l2);
    let cb = PathBatch::ragged(&combined, &lens, d).unwrap();
    let scratch = CorpusRegistry::new();
    let sid = scratch.register(&cb).unwrap();
    let scr_mmd = scratch.mmd2_query(sid, &qb, &opts, Some(&spec)).unwrap();
    assert!(inc_mmd.to_bits() == scr_mmd.to_bits(), "{inc_mmd} vs {scr_mmd}");
}

/// Corpus engine plans: compile via `Plan::compile_corpus`, execute against
/// the query batch, and agree bitwise with driving the registry directly;
/// hostile specs are rejected at compile; corpus records refuse `vjp`.
#[test]
fn corpus_engine_plans_match_registry_and_reject_misuse() {
    let mut rng = Rng::new(806);
    let d = 2;
    let (cdata, clens) = ragged(&mut rng, &[6, 8, 5, 7], d);
    let (qdata, qlens) = ragged(&mut rng, &[5, 7, 6], d);
    let cb = PathBatch::ragged(&cdata, &clens, d).unwrap();
    let qb = PathBatch::ragged(&qdata, &qlens, d).unwrap();
    let registry = Arc::new(CorpusRegistry::new());
    let id = registry.register(&cb).unwrap();
    let opts = KernelOptions::default();
    let shape = ShapeClass::for_batch(&qb).bucketed();
    for lowrank in [None, Some(LowRankSpec::nystrom(3, 5))] {
        let mspec = OpSpec::Mmd2Corpus {
            opts,
            corpus: id,
            lowrank,
        };
        let plan = Plan::compile_corpus(mspec, shape, registry.clone()).unwrap();
        let rec = plan.execute(&qb).unwrap();
        let want = registry
            .mmd2_query(id, &qb, &opts, lowrank.as_ref())
            .unwrap();
        assert_eq!(rec.values(), &[want][..]);
        assert!(matches!(rec.vjp(&[1.0]), Err(SigError::Invalid(_))));
        let gspec = OpSpec::GramCorpus {
            opts,
            corpus: id,
            lowrank,
        };
        let gplan = Plan::compile_corpus(gspec, shape, registry.clone()).unwrap();
        let grec = gplan.execute(&qb).unwrap();
        let gwant = registry
            .gram_query(id, &qb, &opts, lowrank.as_ref())
            .unwrap();
        assert_eq!(grec.values(), &gwant[..]);
    }
    // The plan cache keys corpus plans by (spec, corpus id, shape).
    let cache = PlanCache::new(8);
    let spec = OpSpec::Mmd2Corpus {
        opts,
        corpus: id,
        lowrank: None,
    };
    let a = cache.get_or_compile_corpus(spec, shape, &registry).unwrap();
    let b = cache.get_or_compile_corpus(spec, shape, &registry).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "second corpus plan lookup must hit");
    assert_eq!(cache.stats().hits, 1);
    // A cached plan survives appends (it resolves the id per execute).
    let (extra, elens) = ragged(&mut rng, &[6], d);
    let eb = PathBatch::ragged(&extra, &elens, d).unwrap();
    registry.append(id, &eb).unwrap();
    let post = a.execute(&qb).unwrap();
    let want = registry.mmd2_query(id, &qb, &opts, None).unwrap();
    assert_eq!(post.values(), &[want][..]);
    // Misuse errors.
    assert!(matches!(
        Plan::compile_corpus(OpSpec::Gram(opts), shape, registry.clone()),
        Err(SigError::Invalid(_))
    ));
    assert!(matches!(
        Plan::compile_corpus(
            OpSpec::Mmd2Corpus {
                opts,
                corpus: pysiglib::CorpusId(4040),
                lowrank: None,
            },
            shape,
            registry.clone(),
        ),
        Err(SigError::Invalid(_))
    ));
    assert!(matches!(
        Plan::compile_corpus(
            OpSpec::Mmd2Corpus {
                opts,
                corpus: id,
                lowrank: None,
            },
            ShapeClass::ragged(d + 1, 8),
            registry.clone(),
        ),
        Err(SigError::DimMismatch { .. })
    ));
    // Corpus specs without a registry are rejected by the generic route.
    assert!(matches!(
        Plan::compile(spec, shape),
        Err(SigError::Invalid(_))
    ));
    // Corpus plans take a single (query) batch, not a pair.
    let plan = Plan::compile_corpus(spec, shape, registry.clone()).unwrap();
    assert!(matches!(
        plan.execute_pair(&qb, &qb),
        Err(SigError::Invalid(_))
    ));
}

/// The scheduling-independence property: tiled Gram under 1 worker thread
/// is bit-identical to 4 worker threads (and to the engine's per-entry
/// Gram). Uses `set_thread_override` — not `set_var` — so the sweep is
/// race-free against parallel sibling tests.
#[test]
fn tiled_gram_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(805);
    let d = 3;
    let (xd, xl) = ragged(&mut rng, &[6, 9, 3, 7, 5, 8, 4, 6, 7, 5, 9, 2], d);
    let (yd, yl) = ragged(&mut rng, &[7, 4, 8, 5, 6], d);
    let xb = PathBatch::ragged(&xd, &xl, d).unwrap();
    let yb = PathBatch::ragged(&yd, &yl, d).unwrap();
    for opts in [
        KernelOptions::default(),
        KernelOptions::default().dyadic(1, 0),
        KernelOptions::default().transform(Transform::LeadLag),
    ] {
        let mut per_threads = Vec::new();
        for threads in [1usize, 4] {
            set_thread_override(Some(threads));
            let mut out = vec![0.0; xb.batch() * yb.batch()];
            TileScheduler::with_tile(3)
                .gram_into(&xb, &yb, &opts, &mut out)
                .unwrap();
            per_threads.push(out);
        }
        set_thread_override(None);
        assert_eq!(
            per_threads[0], per_threads[1],
            "tiled Gram must not depend on the thread count"
        );
        // The per-entry values are thread-count independent by the
        // assertion above, so the default setting is a fair reference.
        let engine = try_gram(&xb, &yb, &opts).unwrap();
        assert_eq!(per_threads[0], engine, "tiled vs engine per-entry Gram");
    }
}
