//! End-to-end reliability tests: admission control and crash recovery over
//! real TCP. Overload and deadline rejections must arrive **typed** (wire
//! statuses 2 and 3, never a silent compute or a dropped connection), the
//! retrying client must back off with strictly increasing delays, and a
//! drained server must leave a snapshot a fresh process restores
//! bit-identically.
//!
//! Overload here is driven by real queue caps (`queue_cap`/`global_cap` of
//! 1 and a parked flush window), not failpoints: integration tests link the
//! library without `cfg(test)`, exactly like a release build, so these
//! tests double as proof that the admission path needs no test-only hooks.

use std::sync::Arc;
use std::time::Duration;

use pysiglib::coordinator::{
    serve, Batcher, BatcherConfig, Client, Op, RetryPolicy, Router, WireResponse,
};
use pysiglib::util::rng::Rng;

fn start_server(config: BatcherConfig, router: Router) -> pysiglib::coordinator::ServerHandle {
    let batcher = Arc::new(Batcher::start(Arc::new(router), config));
    serve("127.0.0.1:0", batcher).expect("bind")
}

/// One queue slot, one global slot, and a flush window far longer than the
/// test: the first submitted request parks and every later one is shed.
fn single_slot_config() -> BatcherConfig {
    BatcherConfig {
        max_batch: 1000,
        max_wait: Duration::from_secs(30),
        queue_cap: 1,
        global_cap: 1,
        deadline: None,
    }
}

#[test]
fn overload_is_typed_and_the_client_backs_off_monotonically() {
    let handle = start_server(single_slot_config(), Router::native_only());
    let addr = handle.addr;
    let mut rng = Rng::new(300);
    let path = rng.brownian_path(8, 2, 0.5);

    // Park one request in the queue's single slot from a helper thread (it
    // blocks awaiting its response until the server drains on shutdown).
    let parked_path = path.clone();
    let parked = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.signature(&parked_path, 8, 2, 3).unwrap()
    });
    // Wait until the parked request owns the slot.
    std::thread::sleep(Duration::from_millis(50));

    let mut client = Client::connect(addr)
        .unwrap()
        .with_retry(RetryPolicy {
            max_attempts: 4,
            base_ms: 1,
            cap_ms: 8,
            seed: 7,
        });
    let op = Op::Signature {
        depth: 3,
        transform: 0,
    };
    let resp = client.call_with_retry(op, 8, 2, &path).unwrap();
    assert!(
        matches!(resp, WireResponse::Overloaded { retry_after_ms } if retry_after_ms >= 1),
        "expected a typed overload after exhausting retries, got {resp:?}"
    );
    let backoffs = client.backoffs_ms();
    assert_eq!(backoffs.len(), 3, "max_attempts 4 = 3 slept backoffs: {backoffs:?}");
    for w in backoffs.windows(2) {
        assert!(w[1] > w[0], "backoff must increase monotonically: {backoffs:?}");
    }

    // Shutdown drains: the parked request is flushed, not dropped.
    handle.stop();
    let parked_resp = parked.join().expect("parked client thread").unwrap();
    let want = pysiglib::sig::sig(&path, 8, 2, 3);
    let err = pysiglib::util::linalg::max_abs_diff(&parked_resp, &want);
    assert!(err < 1e-12, "drained request must still compute: {err}");
}

#[test]
fn an_expired_deadline_is_a_typed_rejection_not_a_silent_compute() {
    let config = BatcherConfig {
        deadline: Some(Duration::ZERO),
        ..BatcherConfig::default()
    };
    let handle = start_server(config, Router::native_only());
    let mut client = Client::connect(handle.addr).unwrap();
    let mut rng = Rng::new(301);
    let path = rng.brownian_path(8, 2, 0.5);
    let op = Op::Signature {
        depth: 3,
        transform: 0,
    };
    let resp = client.call_typed(op, 8, 2, path).unwrap();
    assert_eq!(resp, WireResponse::DeadlineExceeded, "{resp:?}");
    handle.stop();
}

#[test]
fn snapshot_rpc_without_a_configured_path_is_an_error_not_a_panic() {
    let handle = start_server(BatcherConfig::default(), Router::native_only());
    let mut client = Client::connect(handle.addr).unwrap();
    let err = client.snapshot_corpus().unwrap().unwrap_err();
    assert!(err.contains("no snapshot path"), "{err}");
    handle.stop();
}

#[test]
fn a_drained_server_leaves_a_snapshot_a_fresh_server_restores_bit_identically() {
    let dir = std::env::temp_dir().join(format!("pysiglib-reliability-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut rng = Rng::new(302);
    let d = 2;
    let corpus: Vec<Vec<f64>> = (0..6).map(|_| rng.brownian_path(10, d, 0.35)).collect();
    let corpus_refs: Vec<&[f64]> = corpus.iter().map(|p| p.as_slice()).collect();
    let queries: Vec<Vec<f64>> = (0..3).map(|_| rng.brownian_path(8, d, 0.4)).collect();
    let query_refs: Vec<&[f64]> = queries.iter().map(|p| p.as_slice()).collect();

    // First life: register, warm the corpus caches, snapshot over the wire,
    // then drain (which snapshots again — the shutdown path must overwrite
    // cleanly rather than corrupt the explicit snapshot).
    let (id, live_mmd) = {
        let router = Router::native_only().with_snapshot_dir(dir.clone());
        let handle = start_server(BatcherConfig::default(), router);
        let mut client = Client::connect(handle.addr).unwrap();
        let id = client.register_corpus(&corpus_refs, d).unwrap().unwrap();
        let mmd = client.mmd2_corpus(id, &query_refs, d, 0).unwrap().unwrap();
        assert_eq!(client.snapshot_corpus().unwrap().unwrap(), 1);
        handle.stop();
        (id, mmd)
    };
    let file = dir.join("corpus.snapshot");
    assert!(file.exists(), "drain must leave the snapshot in place");

    // Second life: restore before serving, then answer the same query
    // without re-registering — bit-identical to the first life.
    let mut router = Router::native_only().with_snapshot_dir(dir.clone());
    assert_eq!(router.restore_corpora().unwrap(), 1);
    let handle = start_server(BatcherConfig::default(), router);
    let mut client = Client::connect(handle.addr).unwrap();
    let restored_mmd = client.mmd2_corpus(id, &query_refs, d, 0).unwrap().unwrap();
    assert!(
        live_mmd.to_bits() == restored_mmd.to_bits(),
        "restored server diverged: {live_mmd:?} vs {restored_mmd:?}"
    );
    handle.stop();
    std::fs::remove_dir_all(&dir).ok();
}
