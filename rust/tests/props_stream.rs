//! Property tests for the streaming corpus subsystem — the acceptance bar
//! for in-place path extension: extend-then-query is **bit-identical** to
//! registering the grown corpus from scratch (uniform and ragged corpora,
//! every transform, exact and low-rank paths, Row and Blocked solvers),
//! eviction is bit-identical to registering the surviving suffix, and the
//! weighted window estimator's analytic decay gradient matches finite
//! differences. Occupancy (extensions solve only the border strip) is
//! asserted in `props_stream_occupancy.rs`, which owns its process so the
//! global cell counters are not shared with these tests.

use pysiglib::corpus::CorpusRegistry;
use pysiglib::kernel::{KernelOptions, LowRankSpec, SolverKind};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

/// Build a ragged batch's backing store.
fn ragged(rng: &mut Rng, lens: &[usize], d: usize) -> (Vec<f64>, Vec<usize>) {
    let mut data = Vec::new();
    for &l in lens {
        data.extend(rng.brownian_path(l, d, 0.35));
    }
    (data, lens.to_vec())
}

/// Drive one scenario: the *grown* corpus (path `k` carrying `add` extra
/// points) is the ground truth; the incremental side registers the
/// truncated corpus, warms every query family, then streams the tail into
/// path `k` via `extend_path` — in `splits` instalments, exercising
/// repeated strip extensions. MMD² and Gram, exact and (when `spec` is
/// set) low-rank, must agree bitwise with a from-scratch registration.
#[allow(clippy::too_many_arguments)]
fn check_extend_matches_scratch(
    d: usize,
    grown_lens: &[usize],
    k: usize,
    add: usize,
    splits: usize,
    opts: &KernelOptions,
    spec: Option<&LowRankSpec>,
    seed: u64,
    label: &str,
) {
    let mut rng = Rng::new(seed);
    let (grown, glens) = ragged(&mut rng, grown_lens, d);
    let (q, lq) = ragged(&mut rng, &[grown_lens[k].max(3), 4], d);
    let qb = PathBatch::ragged(&q, &lq, d).unwrap();

    // Truncate path k by `add` points to produce the pre-stream corpus;
    // the removed tail is what gets streamed back in.
    let l_old = glens[k] - add;
    let start: usize = glens.iter().take(k).sum::<usize>() * d;
    let cut = start + l_old * d;
    let tail_end = start + glens[k] * d;
    let mut base = grown[..cut].to_vec();
    base.extend_from_slice(&grown[tail_end..]);
    let mut base_lens = glens.clone();
    base_lens[k] = l_old;
    let bb = PathBatch::ragged(&base, &base_lens, d).unwrap();
    let tail = &grown[cut..tail_end];

    // Incremental: register the truncated corpus, WARM the caches, then
    // stream the tail in `splits` slices.
    let inc = CorpusRegistry::new();
    let id = inc.register(&bb).unwrap();
    inc.mmd2_query(id, &qb, opts, spec).unwrap();
    inc.gram_query(id, &qb, opts, spec).unwrap();
    let per = (add / splits).max(1) * d;
    let mut fed = 0;
    while fed < tail.len() {
        let chunk = &tail[fed..(fed + per).min(tail.len())];
        let new_len = inc.extend_path(id, k, chunk).unwrap();
        fed += chunk.len();
        assert_eq!(new_len, l_old + fed / d, "{label}: reported length");
    }
    let inc_mmd = inc.mmd2_query(id, &qb, opts, spec).unwrap();
    let inc_gram = inc.gram_query(id, &qb, opts, spec).unwrap();
    // The post-extension queries must be warm (state extended in place):
    // only the single cold build of the pre-extension query remains.
    assert_eq!(inc.stats().cold_builds, 1, "{label}: rebuilt instead of extended");
    assert_eq!(inc.stats().extended, splits as u64, "{label}: extend count");

    // From scratch: register the grown corpus, query cold.
    let scratch = CorpusRegistry::new();
    let gb = PathBatch::ragged(&grown, &glens, d).unwrap();
    let sid = scratch.register(&gb).unwrap();
    let scr_mmd = scratch.mmd2_query(sid, &qb, opts, spec).unwrap();
    let scr_gram = scratch.gram_query(sid, &qb, opts, spec).unwrap();

    assert!(
        inc_mmd.to_bits() == scr_mmd.to_bits(),
        "{label}: mmd2 {inc_mmd:?} vs {scr_mmd:?}"
    );
    assert_eq!(inc_gram.len(), scr_gram.len(), "{label}");
    for (i, (a, b)) in inc_gram.iter().zip(scr_gram.iter()).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{label}: gram[{i}] {a:?} vs {b:?}"
        );
    }
}

#[test]
fn extend_then_query_bit_identical_exact_uniform() {
    let opts = KernelOptions::default();
    check_extend_matches_scratch(3, &[9; 5], 2, 4, 1, &opts, None, 900, "exact uniform");
}

#[test]
fn extend_then_query_bit_identical_exact_ragged() {
    // A length-1 partner rides along: degenerate pairs stay the constant 1.
    let opts = KernelOptions::default();
    let lens = [5usize, 9, 1, 7, 4];
    check_extend_matches_scratch(2, &lens, 1, 3, 1, &opts, None, 901, "exact ragged");
}

#[test]
fn extend_then_query_bit_identical_in_instalments() {
    // Streaming one point at a time composes strips on strips.
    let opts = KernelOptions::default();
    check_extend_matches_scratch(2, &[6, 10, 5], 1, 4, 4, &opts, None, 902, "instalments");
}

#[test]
fn extend_then_query_bit_identical_under_transforms() {
    for (tr, seed) in [
        (Transform::TimeAug, 903u64),
        (Transform::LeadLag, 904),
        (Transform::LeadLagTimeAug, 905),
    ] {
        let opts = KernelOptions::default().transform(tr);
        let label = format!("{tr:?}");
        check_extend_matches_scratch(2, &[5, 8, 6, 7], 2, 3, 1, &opts, None, seed, &label);
    }
}

#[test]
fn extend_then_query_bit_identical_dyadic() {
    let opts = KernelOptions::default().dyadic(1, 1);
    check_extend_matches_scratch(2, &[6, 7, 5], 0, 2, 1, &opts, None, 906, "dyadic");
}

#[test]
fn extend_then_query_bit_identical_blocked_solver() {
    // The Blocked solver has a different FP schedule than the border
    // sweeps, so extensions recompute the touched row/column through the
    // tile scheduler instead — still bit-identical to scratch.
    let opts = KernelOptions::default().solver(SolverKind::Blocked);
    check_extend_matches_scratch(2, &[7, 9, 6], 1, 3, 1, &opts, None, 907, "blocked");
}

#[test]
fn extend_then_query_bit_identical_nystrom() {
    // k = 5 lies outside the rank-4 landmark pool: the feature map is
    // frozen and only the extended path refeaturises.
    let spec = LowRankSpec::nystrom(4, 11);
    let opts = KernelOptions::default();
    check_extend_matches_scratch(2, &[7; 6], 5, 3, 1, &opts, Some(&spec), 908, "nystrom tail");
    // k = 0 is a landmark: extending it moves the landmark draw, so the
    // whole low-rank state rebuilds — still bitwise equal to scratch.
    check_extend_matches_scratch(2, &[7; 6], 0, 3, 1, &opts, Some(&spec), 909, "nystrom landmark");
}

#[test]
fn extend_then_query_bit_identical_random_sig() {
    let spec = LowRankSpec::random_sig(8, 3, 13);
    let opts = KernelOptions::default();
    check_extend_matches_scratch(2, &[6, 8, 3, 7], 1, 2, 1, &opts, Some(&spec), 910, "randsig");
}

/// Evicting to the newest `keep` paths must be bit-identical to registering
/// the surviving suffix from scratch.
fn check_evict_matches_suffix(
    d: usize,
    lens: &[usize],
    keep: usize,
    spec: Option<&LowRankSpec>,
    seed: u64,
    label: &str,
) {
    let opts = KernelOptions::default();
    let mut rng = Rng::new(seed);
    let (data, lv) = ragged(&mut rng, lens, d);
    let (q, lq) = ragged(&mut rng, &[6, 4], d);
    let qb = PathBatch::ragged(&q, &lq, d).unwrap();

    let inc = CorpusRegistry::new();
    let id = inc.register(&PathBatch::ragged(&data, &lv, d).unwrap()).unwrap();
    inc.mmd2_query(id, &qb, &opts, spec).unwrap();
    inc.gram_query(id, &qb, &opts, spec).unwrap();
    let kept = inc.evict(id, keep).unwrap();
    assert_eq!(kept, keep, "{label}");
    assert_eq!(inc.path_count(id), Some(keep), "{label}");
    assert_eq!(inc.stats().evicted, 1, "{label}");
    let inc_mmd = inc.mmd2_query(id, &qb, &opts, spec).unwrap();
    let inc_gram = inc.gram_query(id, &qb, &opts, spec).unwrap();

    let drop_pts: usize = lens[..lens.len() - keep].iter().sum();
    let suffix = &data[drop_pts * d..];
    let slens = &lens[lens.len() - keep..];
    let scratch = CorpusRegistry::new();
    let sid = scratch.register(&PathBatch::ragged(suffix, slens, d).unwrap()).unwrap();
    let scr_mmd = scratch.mmd2_query(sid, &qb, &opts, spec).unwrap();
    let scr_gram = scratch.gram_query(sid, &qb, &opts, spec).unwrap();

    assert!(
        inc_mmd.to_bits() == scr_mmd.to_bits(),
        "{label}: mmd2 {inc_mmd:?} vs {scr_mmd:?}"
    );
    for (i, (a, b)) in inc_gram.iter().zip(scr_gram.iter()).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{label}: gram[{i}] {a:?} vs {b:?}"
        );
    }
}

#[test]
fn evict_then_query_bit_identical_exact() {
    check_evict_matches_suffix(2, &[5, 9, 2, 7, 4, 6], 3, None, 920, "evict exact");
}

#[test]
fn evict_then_query_bit_identical_random_sig() {
    let spec = LowRankSpec::random_sig(6, 3, 7);
    check_evict_matches_suffix(2, &[6; 5], 2, Some(&spec), 921, "evict randsig");
}

#[test]
fn evict_then_query_bit_identical_nystrom() {
    // Eviction shifts the landmark prefix, forcing a Nyström rebuild —
    // which must land exactly on the scratch registration's state.
    let spec = LowRankSpec::nystrom(2, 19);
    check_evict_matches_suffix(2, &[6; 5], 3, Some(&spec), 922, "evict nystrom");
}

#[test]
fn evict_edge_cases() {
    let mut rng = Rng::new(923);
    let (data, lens) = ragged(&mut rng, &[5, 6, 7], 2);
    let reg = CorpusRegistry::new();
    let id = reg.register(&PathBatch::ragged(&data, &lens, 2).unwrap()).unwrap();
    assert!(reg.evict(id, 0).is_err(), "keep = 0 would empty the corpus");
    assert_eq!(reg.evict(id, 8).unwrap(), 3, "keep >= n is a no-op");
    assert_eq!(reg.path_count(id), Some(3));
}

#[test]
fn evict_by_age_drops_exactly_the_stale_prefix() {
    // Age clock: registration is tick 0, each append batch advances it by
    // one; evict_by_age keeps the trailing run younger than the bound,
    // backstopped by the keep floor. The drop must bit-match a registry
    // that was registered with the survivors directly.
    let d = 2;
    let opts = KernelOptions::default();
    let mut rng = Rng::new(925);
    let (data, lens) = ragged(&mut rng, &[5, 6], d);
    let (a1, l1) = ragged(&mut rng, &[7], d);
    let (a2, l2) = ragged(&mut rng, &[4, 6], d);
    let (q, lq) = ragged(&mut rng, &[6, 4], d);
    let qb = PathBatch::ragged(&q, &lq, d).unwrap();

    let inc = CorpusRegistry::new();
    let id = inc.register(&PathBatch::ragged(&data, &lens, d).unwrap()).unwrap();
    inc.append(id, &PathBatch::ragged(&a1, &l1, d).unwrap()).unwrap(); // tick 1
    inc.append(id, &PathBatch::ragged(&a2, &l2, d).unwrap()).unwrap(); // tick 2
    inc.mmd2_query(id, &qb, &opts, None).unwrap();

    // A generous bound keeps everything (ages are 2, 2, 1, 0, 0).
    assert_eq!(inc.evict_by_age(id, 2, 0).unwrap(), 5);
    // max_age = 1 drops the two tick-0 registrations.
    assert_eq!(inc.evict_by_age(id, 1, 0).unwrap(), 3);
    assert_eq!(inc.path_count(id), Some(3));
    let inc_mmd = inc.mmd2_query(id, &qb, &opts, None).unwrap();

    // Scratch registry holding just the survivors: paths a1 + a2.
    let mut surv = a1.clone();
    surv.extend_from_slice(&a2);
    let slens = [7usize, 4, 6];
    let scratch = CorpusRegistry::new();
    let sid = scratch.register(&PathBatch::ragged(&surv, &slens, d).unwrap()).unwrap();
    let scr_mmd = scratch.mmd2_query(sid, &qb, &opts, None).unwrap();
    assert!(inc_mmd.to_bits() == scr_mmd.to_bits(), "{inc_mmd:?} vs {scr_mmd:?}");

    // The keep floor overrides an aggressive age bound: after one more
    // append the ages are [2, 1, 1, 0], so max_age = 0 alone would keep 1 —
    // the floor holds 3.
    let (a3, l3) = ragged(&mut rng, &[5], d);
    inc.append(id, &PathBatch::ragged(&a3, &l3, d).unwrap()).unwrap(); // tick 3
    assert_eq!(inc.evict_by_age(id, 0, 3).unwrap(), 3);
    // Without a floor, age 0 keeps only the tick-3 path.
    assert_eq!(inc.evict_by_age(id, 0, 0).unwrap(), 1);
    assert_eq!(inc.path_count(id), Some(1));
}

#[test]
fn extend_then_evict_composes_bitwise() {
    // Stream points into the newest path, then slide the window — the
    // surviving state must equal registering the final shape directly.
    let d = 2;
    let opts = KernelOptions::default();
    let mut rng = Rng::new(924);
    let (data, lens) = ragged(&mut rng, &[6, 5, 8], d);
    let ext = rng.brownian_path(3, d, 0.35);
    let (q, lq) = ragged(&mut rng, &[6, 4], d);
    let qb = PathBatch::ragged(&q, &lq, d).unwrap();

    let inc = CorpusRegistry::new();
    let id = inc.register(&PathBatch::ragged(&data, &lens, d).unwrap()).unwrap();
    inc.mmd2_query(id, &qb, &opts, None).unwrap();
    inc.extend_path(id, 2, &ext).unwrap();
    inc.evict(id, 2).unwrap();
    let inc_mmd = inc.mmd2_query(id, &qb, &opts, None).unwrap();

    // Final shape: paths 1 and 2, with path 2 carrying the streamed tail.
    let mut fin = data[6 * d..].to_vec();
    fin.extend_from_slice(&ext);
    let flens = [5usize, 8 + 3];
    let scratch = CorpusRegistry::new();
    let sid = scratch.register(&PathBatch::ragged(&fin, &flens, d).unwrap()).unwrap();
    let scr_mmd = scratch.mmd2_query(sid, &qb, &opts, None).unwrap();
    assert!(inc_mmd.to_bits() == scr_mmd.to_bits(), "{inc_mmd:?} vs {scr_mmd:?}");
}

#[test]
fn mmd2_window_decay_gradient_matches_finite_differences() {
    let d = 2;
    let opts = KernelOptions::default();
    let mut rng = Rng::new(930);
    let (c, lc) = ragged(&mut rng, &[7; 5], d);
    let (w, lw) = ragged(&mut rng, &[7, 6, 8, 7], d);
    let reg = CorpusRegistry::new();
    let id = reg.register(&PathBatch::ragged(&c, &lc, d).unwrap()).unwrap();
    let wb = PathBatch::ragged(&w, &lw, d).unwrap();

    for decay in [0.35, 0.62, 0.9] {
        let (v, g) = reg.mmd2_window_with_grad(id, &wb, &opts, decay).unwrap();
        assert!(v.is_finite(), "value at decay {decay}");
        let h = 1e-5;
        let up = reg.mmd2_window(id, &wb, &opts, decay + h).unwrap();
        let dn = reg.mmd2_window(id, &wb, &opts, decay - h).unwrap();
        let fd = (up - dn) / (2.0 * h);
        let tol = 1e-4 * g.abs().max(1.0);
        assert!(
            (g - fd).abs() <= tol,
            "decay {decay}: analytic {g} vs FD {fd}"
        );
    }
}

#[test]
fn mmd2_window_at_decay_one_recovers_the_uniform_estimator() {
    let d = 2;
    let opts = KernelOptions::default();
    let mut rng = Rng::new(931);
    let (c, lc) = ragged(&mut rng, &[6; 4], d);
    let (w, lw) = ragged(&mut rng, &[6, 5, 7], d);
    let reg = CorpusRegistry::new();
    let id = reg.register(&PathBatch::ragged(&c, &lc, d).unwrap()).unwrap();
    let wb = PathBatch::ragged(&w, &lw, d).unwrap();
    let weighted = reg.mmd2_window(id, &wb, &opts, 1.0).unwrap();
    let uniform = reg.mmd2_query(id, &wb, &opts, None).unwrap();
    // Same estimator up to floating-point summation order.
    assert!(
        (weighted - uniform).abs() <= 1e-12 * uniform.abs().max(1.0),
        "{weighted} vs {uniform}"
    );
}

#[test]
fn mmd2_window_rejects_bad_decay() {
    let d = 2;
    let opts = KernelOptions::default();
    let mut rng = Rng::new(932);
    let (c, lc) = ragged(&mut rng, &[5; 3], d);
    let (w, lw) = ragged(&mut rng, &[5, 5], d);
    let reg = CorpusRegistry::new();
    let id = reg.register(&PathBatch::ragged(&c, &lc, d).unwrap()).unwrap();
    let wb = PathBatch::ragged(&w, &lw, d).unwrap();
    for bad in [0.0, -0.5, 1.5, f64::NAN] {
        assert!(
            reg.mmd2_window(id, &wb, &opts, bad).is_err(),
            "decay {bad} must be rejected"
        );
    }
}

#[test]
fn extend_path_rejects_bad_shapes() {
    let d = 2;
    let mut rng = Rng::new(933);
    let (data, lens) = ragged(&mut rng, &[5, 6], d);
    let reg = CorpusRegistry::new();
    let id = reg.register(&PathBatch::ragged(&data, &lens, d).unwrap()).unwrap();
    // Not a whole number of dim-d samples.
    assert!(reg.extend_path(id, 0, &[1.0, 2.0, 3.0]).is_err());
    // Path index out of range.
    assert!(reg.extend_path(id, 2, &[1.0, 2.0]).is_err());
    // Empty extension is a no-op returning the current length.
    assert_eq!(reg.extend_path(id, 0, &[]).unwrap(), 5);
}
