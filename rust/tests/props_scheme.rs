//! Goursat-scheme properties (the PR's acceptance surface): the order-2
//! Richardson scheme must converge to the same limit as order-1 and beat
//! it at matched λ; lane-batched solves must reproduce the scalar path
//! **bit for bit** under either scheme; the order-2 backward must match
//! finite differences of the order-2 forward; and `target_eps` resolution
//! must be deterministic, idempotent, and cost-monotone in ε — with
//! hostile targets rejected as typed errors at plan compile.

use pysiglib::engine::{OpSpec, Plan, ShapeClass};
use pysiglib::kernel::scheme::cell_cost;
use pysiglib::kernel::{
    resolve_target_eps, try_gram_vjp_with_lanes, try_sig_kernel, try_sig_kernel_vjp,
    KernelOptions, Scheme,
};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;
use pysiglib::{Path, PathBatch};

/// Relative error of `k` against `reference` (the accuracy bench's metric).
fn rel_err(k: f64, reference: f64) -> f64 {
    (k - reference).abs() / reference.abs().max(1.0)
}

fn kernel_at(x: &[f64], y: &[f64], lx: usize, ly: usize, d: usize, opts: KernelOptions) -> f64 {
    let xp = Path::new(x, lx, d).unwrap();
    let yp = Path::new(y, ly, d).unwrap();
    try_sig_kernel(xp, yp, &opts).unwrap()
}

/// Order-2 and order-1 walk the same dyadic ladder toward the same limit:
/// errors against a λ = 6 reference shrink with λ for both schemes, the
/// Richardson combination is no worse than order-1 at matched λ, and at
/// λ = (0, 0) the two schemes coincide bitwise (the degenerate guard).
#[test]
fn both_schemes_converge_to_the_same_limit() {
    let mut rng = Rng::new(941);
    let d = 2;
    for len in [12usize, 20] {
        let x = rng.brownian_path(len, d, 0.3);
        let y = rng.brownian_path(len + 3, d, 0.3);
        let at = |scheme: Scheme, lam: u32| {
            kernel_at(
                &x,
                &y,
                len,
                len + 3,
                d,
                KernelOptions::default().dyadic(lam, lam).scheme(scheme),
            )
        };
        let reference = at(Scheme::Order1, 6);
        let e1 = |lam| rel_err(at(Scheme::Order1, lam), reference);
        let e2 = |lam| rel_err(at(Scheme::Order2, lam), reference);
        // Convergence: both schemes tighten by λ = 4 relative to λ = 1.
        assert!(e1(4) < e1(1), "order1 not converging: {} vs {}", e1(4), e1(1));
        assert!(e2(4) < e2(1), "order2 not converging: {} vs {}", e2(4), e2(1));
        // Same limit: both land close to the reference by λ = 5.
        assert!(e1(5) < 5e-3, "order1 off the limit: {}", e1(5));
        assert!(e2(5) < 5e-3, "order2 off the limit: {}", e2(5));
        // Richardson is no worse than order-1 at matched λ.
        for lam in [2u32, 3, 4] {
            assert!(
                e2(lam) <= e1(lam) + 1e-12,
                "order2@{lam} = {} worse than order1@{lam} = {}",
                e2(lam),
                e1(lam)
            );
        }
        // λ = (0, 0) is degenerate: the coarse grid coincides with the fine
        // one, so order-2 must return the order-1 value exactly.
        assert_eq!(at(Scheme::Order2, 0), at(Scheme::Order1, 0));
    }
}

/// Lane widths 0 / 4 / 8 must reproduce the scalar Gram bitwise under both
/// schemes (forward and weighted backward): lane batching is pure schedule,
/// independent of the Goursat discretisation order.
#[test]
fn lanes_bitmatch_scalar_for_every_width_and_scheme() {
    let mut rng = Rng::new(942);
    let d = 2;
    let xu = rng.brownian_batch(9, 7, d, 0.4);
    let yu = rng.brownian_batch(11, 6, d, 0.4);
    let xb = PathBatch::uniform(&xu, 9, 7, d).unwrap();
    let yb = PathBatch::uniform(&yu, 11, 6, d).unwrap();
    let mut w = vec![0.0; 9 * 11];
    rng.fill_normal(&mut w);
    let opts_matrix = [
        KernelOptions::default().dyadic(1, 1).scheme(Scheme::Order1),
        KernelOptions::default().scheme(Scheme::Order2), // degenerate λ = (0, 0)
        KernelOptions::default().dyadic(1, 1).scheme(Scheme::Order2),
        KernelOptions::default().dyadic(2, 1).scheme(Scheme::Order2),
        KernelOptions::default()
            .dyadic(1, 1)
            .scheme(Scheme::Order2)
            .transform(Transform::TimeAug),
    ];
    for opts in opts_matrix {
        let shape = ShapeClass::for_pair(&xb, &yb);
        let scalar = Plan::compile_forward(OpSpec::Gram(opts), shape)
            .unwrap()
            .with_lane_width(0);
        let want = scalar.execute_pair(&xb, &yb).unwrap().into_values();
        let want_grad = try_gram_vjp_with_lanes(&xb, &yb, &w, &opts, 0).unwrap();
        for width in [4usize, 8] {
            let plan = Plan::compile_forward(OpSpec::Gram(opts), shape)
                .unwrap()
                .with_lane_width(width);
            let got = plan.execute_pair(&xb, &yb).unwrap().into_values();
            assert_eq!(got, want, "forward width={width} opts={opts:?}");
            let got_grad = try_gram_vjp_with_lanes(&xb, &yb, &w, &opts, width).unwrap();
            assert_eq!(got_grad, want_grad, "backward width={width} opts={opts:?}");
        }
    }
}

/// The order-2 backward (fine + coarse adjoint sweeps with Richardson
/// seeds) must match central finite differences of the order-2 forward in
/// every path coordinate.
#[test]
fn order2_backward_matches_finite_differences() {
    let mut rng = Rng::new(943);
    let d = 2;
    let (lx, ly) = (7usize, 6usize);
    let x = rng.brownian_path(lx, d, 0.4);
    let y = rng.brownian_path(ly, d, 0.4);
    let opts = KernelOptions::default().dyadic(2, 1).scheme(Scheme::Order2);
    let gout = 1.3;
    let xp = Path::new(&x, lx, d).unwrap();
    let yp = Path::new(&y, ly, d).unwrap();
    let (gx, gy) = try_sig_kernel_vjp(xp, yp, &opts, gout).unwrap();
    let eps = 1e-6;
    for i in 0..lx * d {
        let mut xp1 = x.clone();
        let mut xm1 = x.clone();
        xp1[i] += eps;
        xm1[i] -= eps;
        let fd = gout * (kernel_at(&xp1, &y, lx, ly, d, opts) - kernel_at(&xm1, &y, lx, ly, d, opts))
            / (2.0 * eps);
        assert!(
            (fd - gx[i]).abs() < 1e-4 * (1.0 + fd.abs()),
            "x[{i}]: fd={fd} vjp={}",
            gx[i]
        );
    }
    for j in 0..ly * d {
        let mut yp1 = y.clone();
        let mut ym1 = y.clone();
        yp1[j] += eps;
        ym1[j] -= eps;
        let fd = gout * (kernel_at(&x, &yp1, lx, ly, d, opts) - kernel_at(&x, &ym1, lx, ly, d, opts))
            / (2.0 * eps);
        assert!(
            (fd - gy[j]).abs() < 1e-4 * (1.0 + fd.abs()),
            "y[{j}]: fd={fd} vjp={}",
            gy[j]
        );
    }
}

/// ε-resolution is deterministic and idempotent, and tightening ε can only
/// move the choice to an equal-or-costlier (scheme, λ): the feasible set
/// shrinks as ε falls, and candidates are ranked cheapest-first.
#[test]
fn target_eps_resolution_is_monotone_and_idempotent() {
    let mut rng = Rng::new(944);
    let d = 2;
    let xu = rng.brownian_batch(6, 14, d, 0.3);
    let yu = rng.brownian_batch(5, 12, d, 0.3);
    let xb = PathBatch::uniform(&xu, 6, 14, d).unwrap();
    let yb = PathBatch::uniform(&yu, 5, 12, d).unwrap();
    let mut last_cost = 0u128;
    for eps in [0.5, 0.1, 0.02, 5e-3, 1e-3, 1e-4, 1e-5, 1e-6] {
        let opts = KernelOptions::default().target_eps(eps);
        let resolved = resolve_target_eps(&xb, &yb, &opts).unwrap();
        // Deterministic: a second resolution of the same request agrees.
        assert_eq!(resolved, resolve_target_eps(&xb, &yb, &opts).unwrap());
        // Idempotent: the resolved options carry no target, so resolving
        // them again is the identity.
        assert_eq!(resolved.target_eps.get(), None);
        assert_eq!(resolved, resolve_target_eps(&xb, &yb, &resolved).unwrap());
        let cost = cell_cost(resolved.scheme, resolved.dyadic_x, resolved.dyadic_y);
        assert!(
            cost >= last_cost,
            "eps={eps}: cost {cost} fell below the looser target's {last_cost}"
        );
        last_cost = cost;
    }
}

/// Hostile ε values (zero, negative, NaN, ∞) must surface as typed errors
/// at plan compile — on the adaptive-capable specs — and the fixed-grid
/// specs must refuse any target at all rather than silently ignore it.
#[test]
fn hostile_target_eps_is_rejected_at_plan_compile() {
    let mut rng = Rng::new(945);
    let d = 2;
    let xu = rng.brownian_batch(3, 6, d, 0.3);
    let xb = PathBatch::uniform(&xu, 3, 6, d).unwrap();
    let shape = ShapeClass::for_pair(&xb, &xb);
    for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        let opts = KernelOptions::default().target_eps(bad);
        for spec in [OpSpec::SigKernel(opts), OpSpec::Gram(opts)] {
            assert!(
                Plan::compile_forward(spec, shape).is_err(),
                "eps={bad} accepted by {spec:?}"
            );
        }
    }
    // A well-formed target still compiles on the adaptive specs.
    let good = KernelOptions::default().target_eps(1e-3);
    assert!(Plan::compile_forward(OpSpec::SigKernel(good), shape).is_ok());
    assert!(Plan::compile_forward(OpSpec::Gram(good), shape).is_ok());
}
