//! Low-rank subsystem acceptance tests: exact recovery at full rank,
//! monotone MMD² convergence in rank on a seeded corpus, and
//! finite-difference gradient checks for the low-rank vjps through
//! `ExecutionRecord::vjp`.

use pysiglib::engine::{Gradients, OpSpec, Plan, ShapeClass};
use pysiglib::kernel::lowrank::LowRankMethod;
use pysiglib::kernel::{
    try_gram, try_gram_lowrank, try_mmd2, try_mmd2_lowrank, try_mmd2_lowrank_with_grad,
    FeatureMap, KernelOptions, LowRankFeatures, LowRankSpec, NystromFeatures, SketchKind,
};
use pysiglib::util::linalg::max_abs_diff;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

fn fd_check(fd: f64, got: f64, what: &str) {
    assert!(
        (fd - got).abs() < 1e-6 * (1.0 + fd.abs()),
        "{what}: finite difference {fd} vs vjp {got}"
    );
}

/// Nyström with every point as a landmark reproduces the exact Gram to
/// ≤ 1e-8 — through the free-function layer and through a `GramLowRank`
/// engine plan (whose landmarks are drawn from the second batch = x here).
#[test]
fn nystrom_full_rank_recovers_exact_gram() {
    let mut rng = Rng::new(600);
    let (n, l, d) = (6usize, 5usize, 2usize);
    let data = rng.brownian_batch(n, l, d, 0.25);
    let xb = PathBatch::uniform(&data, n, l, d).unwrap();
    let opts = KernelOptions::default();
    let exact = try_gram(&xb, &xb, &opts).unwrap();

    let f = NystromFeatures::try_new(&xb, &opts).unwrap();
    let approx = try_gram_lowrank(&f, &xb, &xb).unwrap();
    let err = max_abs_diff(&approx, &exact);
    assert!(err <= 1e-8, "free-function full-rank recovery: err {err}");

    let plan = Plan::compile_forward(
        OpSpec::GramLowRank {
            opts,
            lowrank: LowRankSpec::nystrom(n, 123),
        },
        ShapeClass::uniform(d, l),
    )
    .unwrap();
    let rec = plan.execute_pair(&xb, &xb).unwrap();
    let err = max_abs_diff(rec.values(), &exact);
    assert!(err <= 1e-8, "engine full-rank recovery: err {err}");
}

/// Same recovery property on a ragged corpus (mixed path lengths).
#[test]
fn nystrom_full_rank_recovers_exact_gram_ragged() {
    let mut rng = Rng::new(601);
    let d = 2;
    let lengths = [4usize, 7, 2, 5, 6];
    let mut data = Vec::new();
    for &l in &lengths {
        data.extend(rng.brownian_path(l, d, 0.25));
    }
    let xb = PathBatch::ragged(&data, &lengths, d).unwrap();
    // Symmetric dyadic orders: Nyström targets the symmetric kernel, and
    // exact recovery is only defined when k(x, y) = k(y, x) holds for the
    // discretised solve too.
    let opts = KernelOptions::default().dyadic(1, 1);
    let exact = try_gram(&xb, &xb, &opts).unwrap();
    let f = NystromFeatures::try_new(&xb, &opts).unwrap();
    let approx = try_gram_lowrank(&f, &xb, &xb).unwrap();
    let err = max_abs_diff(&approx, &exact);
    assert!(err <= 1e-8, "ragged full-rank recovery: err {err}");
}

/// With nested landmark prefixes of the pooled corpus, the biased low-rank
/// MMD² is a quadratic form in the Nyström Gram, whose error is PSD and
/// Loewner-decreasing in the landmark set — so the approximation approaches
/// the exact MMD² from below, monotonically, and hits it at full rank.
#[test]
fn lowrank_mmd2_converges_monotonically_in_rank() {
    let mut rng = Rng::new(602);
    let (b, l, d) = (8usize, 6usize, 2usize);
    let x = rng.brownian_batch(b, l, d, 0.3);
    let y = rng.brownian_batch(b, l, d, 0.5);
    let xb = PathBatch::uniform(&x, b, l, d).unwrap();
    let yb = PathBatch::uniform(&y, b, l, d).unwrap();
    // A refined grid keeps the discretised kernel comfortably PSD, which the
    // Loewner-monotonicity argument relies on.
    let opts = KernelOptions::default().dyadic(1, 1);
    let exact = try_mmd2(&xb, &yb, &opts).unwrap();
    let mut pooled = x.clone();
    pooled.extend_from_slice(&y);
    let mut prev_err = f64::INFINITY;
    for r in [2usize, 4, 8, 16] {
        let zb = PathBatch::uniform(&pooled[..r * l * d], r, l, d).unwrap();
        let f = NystromFeatures::try_new(&zb, &opts).unwrap();
        let lr = try_mmd2_lowrank(&f, &xb, &yb).unwrap();
        // One-sided: wᵀK̂w ≤ wᵀKw since K − K̂ is PSD.
        assert!(lr <= exact + 1e-9, "rank {r}: {lr} > exact {exact}");
        let err = exact - lr;
        assert!(
            err <= prev_err + 1e-9,
            "rank {r}: error {err} worse than previous {prev_err}"
        );
        prev_err = err;
    }
    // Full pooled rank: exact recovery.
    assert!(prev_err.abs() <= 1e-8, "full-rank error {prev_err}");
}

/// FD gradient check for `try_mmd2_lowrank` through `ExecutionRecord::vjp`,
/// for both feature families. Landmarks come from y, and the random sketch
/// from the seed alone, so the map is constant in x and central finite
/// differences of the plan's forward value are the true gradient.
#[test]
fn mmd2_lowrank_record_vjp_matches_fd() {
    let mut rng = Rng::new(603);
    let (bx, by, l, d) = (3usize, 4usize, 4usize, 2usize);
    let x = rng.brownian_batch(bx, l, d, 0.4);
    let y = rng.brownian_batch(by, l, d, 0.5);
    let xb = PathBatch::uniform(&x, bx, l, d).unwrap();
    let yb = PathBatch::uniform(&y, by, l, d).unwrap();
    let opts = KernelOptions::default();
    let specs = [
        ("nystrom", LowRankSpec::nystrom(3, 42)),
        (
            "randsig",
            LowRankSpec {
                method: LowRankMethod::RandomSig {
                    depth: 3,
                    sketch: SketchKind::Gaussian,
                },
                rank: 6,
                seed: 42,
            },
        ),
    ];
    for (name, lowrank) in specs {
        let plan = Plan::compile(
            OpSpec::Mmd2LowRank { opts, lowrank },
            ShapeClass::uniform(d, l),
        )
        .unwrap();
        let rec = plan.execute_pair(&xb, &yb).unwrap();
        let grad = match rec.vjp(&[1.0]).unwrap() {
            Gradients::Single(g) => g,
            _ => panic!("mmd2_lowrank vjp is single-gradient"),
        };
        assert_eq!(grad.len(), bx * l * d);
        let f = |xs: &[f64]| -> f64 {
            let xb = PathBatch::uniform(xs, bx, l, d).unwrap();
            plan.execute_pair(&xb, &yb).unwrap().value()
        };
        let eps = 1e-5;
        for idx in 0..x.len() {
            let mut p = x.clone();
            p[idx] += eps;
            let fp = f(&p);
            p[idx] -= 2.0 * eps;
            let fm = f(&p);
            fd_check((fp - fm) / (2.0 * eps), grad[idx], name);
        }
        // The free-function gradient route agrees with the record route.
        let map = FeatureMap::try_build(&lowrank, &opts, &yb).unwrap();
        let (value, fgrad) = try_mmd2_lowrank_with_grad(&map, &xb, &yb).unwrap();
        assert_eq!(value, rec.value(), "{name}");
        assert_eq!(fgrad, grad, "{name}");
    }
}

/// FD gradient check for the low-rank Gram vjp: with random signature
/// features the map is data-independent, so both the x and y gradients are
/// exact (no frozen-landmark caveat).
#[test]
fn gram_lowrank_record_vjp_matches_fd_for_randsig() {
    let mut rng = Rng::new(604);
    let (bx, by, l, d) = (2usize, 3usize, 4usize, 2usize);
    let x = rng.brownian_batch(bx, l, d, 0.4);
    let y = rng.brownian_batch(by, l, d, 0.4);
    let xb = PathBatch::uniform(&x, bx, l, d).unwrap();
    let yb = PathBatch::uniform(&y, by, l, d).unwrap();
    let opts = KernelOptions::default();
    let lowrank = LowRankSpec {
        method: LowRankMethod::RandomSig {
            depth: 3,
            sketch: SketchKind::Rademacher,
        },
        rank: 5,
        seed: 9,
    };
    let mut w = vec![0.0; bx * by];
    rng.fill_normal(&mut w);
    let plan = Plan::compile(
        OpSpec::GramLowRank { opts, lowrank },
        ShapeClass::uniform(d, l),
    )
    .unwrap();
    let rec = plan.execute_pair(&xb, &yb).unwrap();
    let (gx, gy) = match rec.vjp(&w).unwrap() {
        Gradients::Pair(gx, gy) => (gx, gy),
        _ => panic!("gram vjp is pair-input"),
    };
    let f = |xs: &[f64], ys: &[f64]| -> f64 {
        let xb = PathBatch::uniform(xs, bx, l, d).unwrap();
        let yb = PathBatch::uniform(ys, by, l, d).unwrap();
        let g = plan.execute_pair(&xb, &yb).unwrap().into_values();
        g.iter().zip(w.iter()).map(|(a, b)| a * b).sum()
    };
    let eps = 1e-6;
    for idx in 0..x.len() {
        let mut p = x.clone();
        p[idx] += eps;
        let fp = f(&p, &y);
        p[idx] -= 2.0 * eps;
        let fm = f(&p, &y);
        fd_check((fp - fm) / (2.0 * eps), gx[idx], "gram_lowrank grad_x");
    }
    for idx in 0..y.len() {
        let mut p = y.clone();
        p[idx] += eps;
        let fp = f(&x, &p);
        p[idx] -= 2.0 * eps;
        let fm = f(&x, &p);
        fd_check((fp - fm) / (2.0 * eps), gy[idx], "gram_lowrank grad_y");
    }
}

/// Low-rank plans are first-class engine citizens: cacheable per
/// (spec, shape) with warm hits bit-identical, feature matrices retained on
/// the record, and the KRR variant fit through `execute_fit`.
#[test]
fn lowrank_plans_cache_retain_and_fit() {
    let mut rng = Rng::new(605);
    let (b, l, d) = (6usize, 5usize, 2usize);
    let x = rng.brownian_batch(b, l, d, 0.3);
    let y = rng.brownian_batch(b, l, d, 0.4);
    let xb = PathBatch::uniform(&x, b, l, d).unwrap();
    let yb = PathBatch::uniform(&y, b, l, d).unwrap();
    let opts = KernelOptions::default();
    let lowrank = LowRankSpec::nystrom(4, 11);

    let session = pysiglib::engine::Session::new();
    let spec = OpSpec::GramLowRank { opts, lowrank };
    let shape = ShapeClass::uniform(d, l);
    let p1 = session.forward_plan(spec, shape).unwrap();
    let first = p1.execute_pair(&xb, &yb).unwrap().into_values();
    let p2 = session.forward_plan(spec, shape).unwrap();
    assert!(std::sync::Arc::ptr_eq(&p1, &p2), "lowrank plans must cache");
    assert_eq!(p2.execute_pair(&xb, &yb).unwrap().values(), &first[..]);
    // A different rank is a different plan.
    let p3 = session
        .forward_plan(
            OpSpec::GramLowRank {
                opts,
                lowrank: LowRankSpec::nystrom(2, 11),
            },
            shape,
        )
        .unwrap();
    assert!(!std::sync::Arc::ptr_eq(&p1, &p3));

    // Retained features reproduce the Gram.
    let plan = Plan::compile(spec, shape).unwrap();
    let rec = plan.execute_pair(&xb, &yb).unwrap();
    let (phi_x, phi_y, r) = rec.lowrank_features().expect("features retained");
    let mut manual = vec![0.0; b * b];
    for i in 0..b {
        for j in 0..b {
            manual[i * b + j] = (0..r).map(|q| phi_x[i * r + q] * phi_y[j * r + q]).sum();
        }
    }
    assert!(max_abs_diff(&manual, rec.values()) < 1e-12);

    // KRR low-rank: plan-backed fit predicts the training targets at full
    // rank with a tiny ridge (interpolation, like the exact KRR).
    let targets: Vec<f64> = (0..b).map(|i| (i as f64 * 0.37).sin()).collect();
    let model = pysiglib::kernel::KernelRidge::try_fit_lowrank(
        &xb,
        &targets,
        1e-8,
        LowRankSpec::nystrom(b, 3),
        &opts,
    )
    .unwrap();
    let pred = model.try_predict(&xb).unwrap();
    let err = pysiglib::util::linalg::rel_err(&pred, &targets);
    assert!(err < 1e-3, "full-rank lowrank KRR train rel err {err}");
    assert_eq!(model.weights().len(), model.feature_map().rank());
}

/// Hostile low-rank specs are rejected at plan compilation, not at execute.
#[test]
fn hostile_lowrank_specs_rejected_at_compile() {
    use pysiglib::SigError;
    let opts = KernelOptions::default();
    let shape = ShapeClass::uniform(2, 8);
    assert!(matches!(
        Plan::compile(
            OpSpec::GramLowRank {
                opts,
                lowrank: LowRankSpec::nystrom(0, 1),
            },
            shape
        ),
        Err(SigError::Invalid(_))
    ));
    assert!(matches!(
        Plan::compile(
            OpSpec::Mmd2LowRank {
                opts,
                lowrank: LowRankSpec::random_sig(4, 0, 1),
            },
            shape
        ),
        Err(SigError::ZeroDepth)
    ));
    assert!(matches!(
        Plan::compile(
            OpSpec::Mmd2LowRank {
                opts,
                lowrank: LowRankSpec::random_sig(usize::MAX / 2, 8, 1),
            },
            shape
        ),
        Err(SigError::TooLarge(_))
    ));
    assert!(matches!(
        Plan::compile(
            OpSpec::KrrLowRank {
                opts,
                lowrank: LowRankSpec::nystrom(4, 1),
                lambda: 0.0,
            },
            shape
        ),
        Err(SigError::NonFinite(_))
    ));
}
