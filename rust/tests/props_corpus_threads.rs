//! The thread-count bit-identity property, isolated in its own test binary:
//! it mutates `PYSIGLIB_THREADS` via `std::env::set_var`, and a concurrent
//! `getenv` from a sibling test (every parallel kernel sweep calls
//! `num_threads()`) would be a libc-level data race. One `#[test]` per
//! binary means every env read is sequenced on this thread.

use pysiglib::corpus::TileScheduler;
use pysiglib::kernel::{try_gram, KernelOptions};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

fn ragged(rng: &mut Rng, lens: &[usize], d: usize) -> (Vec<f64>, Vec<usize>) {
    let mut data = Vec::new();
    for &l in lens {
        data.extend(rng.brownian_path(l, d, 0.35));
    }
    (data, lens.to_vec())
}

/// The acceptance property: tiled Gram under `PYSIGLIB_THREADS=1` is
/// bit-identical to `PYSIGLIB_THREADS=4` (and to the engine's per-entry
/// Gram).
#[test]
fn tiled_gram_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(805);
    let d = 3;
    let (xd, xl) = ragged(&mut rng, &[6, 9, 3, 7, 5, 8, 4, 6, 7, 5, 9, 2], d);
    let (yd, yl) = ragged(&mut rng, &[7, 4, 8, 5, 6], d);
    let xb = PathBatch::ragged(&xd, &xl, d).unwrap();
    let yb = PathBatch::ragged(&yd, &yl, d).unwrap();
    let prev = std::env::var("PYSIGLIB_THREADS").ok();
    for opts in [
        KernelOptions::default(),
        KernelOptions::default().dyadic(1, 0),
        KernelOptions::default().transform(Transform::LeadLag),
    ] {
        let mut per_threads = Vec::new();
        for threads in ["1", "4"] {
            std::env::set_var("PYSIGLIB_THREADS", threads);
            let mut out = vec![0.0; xb.batch() * yb.batch()];
            TileScheduler::with_tile(3)
                .gram_into(&xb, &yb, &opts, &mut out)
                .unwrap();
            per_threads.push(out);
        }
        assert_eq!(
            per_threads[0], per_threads[1],
            "tiled Gram must not depend on the thread count"
        );
        // The engine comparison runs under the last-set thread count; the
        // per-entry values are thread-count independent by the assertion
        // above, so any setting is a fair reference.
        let engine = try_gram(&xb, &yb, &opts).unwrap();
        assert_eq!(per_threads[0], engine, "tiled vs engine per-entry Gram");
    }
    match prev {
        Some(v) => std::env::set_var("PYSIGLIB_THREADS", v),
        None => std::env::remove_var("PYSIGLIB_THREADS"),
    }
}
