//! A scrubbing lexer for Rust source: comments and string/char literals are
//! blanked to spaces (newlines preserved), so byte offsets and line numbers
//! in the scrubbed text match the original exactly and rules can scan for
//! tokens without tripping on prose. The pass also collects
//! `// siglint: allow(<rule>) -- <reason>` annotations and the spans of
//! `#[cfg(test)]` / `#[test]` items.

/// One parsed `siglint: allow` annotation.
#[derive(Clone, Debug)]
pub struct AllowSite {
    /// Rule name inside `allow(...)`.
    pub rule: String,
    /// The justification after `--` (never empty; a missing reason is a
    /// [`BadAllow`] instead).
    pub reason: String,
    /// 1-based line the annotation suppresses: the comment's own line for a
    /// trailing comment, else the next line with real code.
    pub target_line: usize,
    /// 1-based line of the comment itself (for unused-allow reporting).
    pub comment_line: usize,
}

/// A `siglint:` comment that does not parse as a well-formed allow.
#[derive(Clone, Debug)]
pub struct BadAllow {
    /// 1-based line of the malformed comment.
    pub line: usize,
    /// What is wrong with it.
    pub message: String,
}

/// Scrub result for one file.
pub struct Scrubbed {
    /// Source with comments and literal contents replaced by spaces;
    /// identical length and line structure to the input.
    pub code: String,
    /// Byte offset of the start of each line (index 0 = line 1).
    pub line_starts: Vec<usize>,
    /// Well-formed allow annotations.
    pub allows: Vec<AllowSite>,
    /// Malformed `siglint:` comments.
    pub bad_allows: Vec<BadAllow>,
    /// Byte spans (start, end) of `#[cfg(test)]` / `#[test]` items.
    pub test_spans: Vec<(usize, usize)>,
}

impl Scrubbed {
    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Whether an offset falls inside test-only code.
    pub fn in_test(&self, offset: usize) -> bool {
        self.test_spans
            .iter()
            .any(|&(s, e)| offset >= s && offset < e)
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Scrub `src` and collect annotations and test spans.
pub fn scrub(src: &str) -> Scrubbed {
    let bytes = src.as_bytes();
    let mut out = bytes.to_vec();
    let mut comments: Vec<(usize, String)> = Vec::new(); // (start offset, text)
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'/' if bytes.get(i + 1) == Some(&b'/') => {
                let start = i;
                while i < bytes.len() && bytes[i] != b'\n' {
                    out[i] = b' ';
                    i += 1;
                }
                comments.push((start, src[start..i].to_string()));
            }
            b'/' if bytes.get(i + 1) == Some(&b'*') => {
                let mut depth = 1;
                out[i] = b' ';
                out[i + 1] = b' ';
                i += 2;
                while i < bytes.len() && depth > 0 {
                    if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else {
                        if bytes[i] != b'\n' {
                            out[i] = b' ';
                        }
                        i += 1;
                    }
                }
            }
            b'r' | b'b' if is_raw_string_start(bytes, i) && (i == 0 || !is_ident(bytes[i - 1])) => {
                // r"...", r#"..."#, br"...", b"..." handled below for plain b.
                let (hashes, quote_at) = raw_string_shape(bytes, i);
                let mut j = i;
                while j < quote_at + 1 {
                    out[j] = b' ';
                    j += 1;
                }
                i = quote_at + 1;
                // Scan to closing quote followed by `hashes` '#'s.
                'raw: while i < bytes.len() {
                    if bytes[i] == b'"' {
                        let mut k = 0;
                        while k < hashes && bytes.get(i + 1 + k) == Some(&b'#') {
                            k += 1;
                        }
                        if k == hashes {
                            for b in out.iter_mut().take(i + 1 + hashes).skip(i) {
                                *b = b' ';
                            }
                            i += 1 + hashes;
                            break 'raw;
                        }
                    }
                    if bytes[i] != b'\n' {
                        out[i] = b' ';
                    }
                    i += 1;
                }
            }
            b'"' => {
                out[i] = b' ';
                i += 1;
                while i < bytes.len() {
                    if bytes[i] == b'\\' {
                        out[i] = b' ';
                        if i + 1 < bytes.len() && bytes[i + 1] != b'\n' {
                            out[i + 1] = b' ';
                        }
                        i += 2;
                    } else if bytes[i] == b'"' {
                        out[i] = b' ';
                        i += 1;
                        break;
                    } else {
                        if bytes[i] != b'\n' {
                            out[i] = b' ';
                        }
                        i += 1;
                    }
                }
            }
            b'\'' => {
                // Char literal vs lifetime: a lifetime is `'ident` not
                // followed by a closing quote; a char literal closes within
                // a few bytes (`'a'`, `'\n'`, `'\u{1F600}'`).
                if let Some(close) = char_literal_end(bytes, i) {
                    for b in out.iter_mut().take(close + 1).skip(i) {
                        *b = b' ';
                    }
                    i = close + 1;
                } else {
                    i += 1; // lifetime; leave as-is
                }
            }
            _ => i += 1,
        }
    }
    let code = String::from_utf8_lossy(&out).into_owned();
    let mut line_starts = vec![0usize];
    for (o, b) in code.bytes().enumerate() {
        if b == b'\n' {
            line_starts.push(o + 1);
        }
    }
    let (allows, bad_allows) = parse_allows(&code, &line_starts, &comments);
    let test_spans = find_test_spans(&code);
    Scrubbed {
        code,
        line_starts,
        allows,
        bad_allows,
        test_spans,
    }
}

fn is_raw_string_start(bytes: &[u8], i: usize) -> bool {
    let mut j = i;
    if bytes.get(j) == Some(&b'b') {
        j += 1;
    }
    if bytes.get(j) != Some(&b'r') {
        return false;
    }
    j += 1;
    while bytes.get(j) == Some(&b'#') {
        j += 1;
    }
    bytes.get(j) == Some(&b'"')
}

/// For a raw string at `i`, return (number of hashes, offset of the opening
/// quote).
fn raw_string_shape(bytes: &[u8], i: usize) -> (usize, usize) {
    let mut j = i;
    if bytes.get(j) == Some(&b'b') {
        j += 1;
    }
    j += 1; // the 'r'
    let mut hashes = 0;
    while bytes.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    (hashes, j)
}

/// If a `'` at `i` opens a char literal, return the offset of its closing
/// quote; `None` for lifetimes.
fn char_literal_end(bytes: &[u8], i: usize) -> Option<usize> {
    let next = *bytes.get(i + 1)?;
    if next == b'\\' {
        // Escaped char: find the next unescaped quote within a small window.
        let mut j = i + 2;
        while j < bytes.len() && j < i + 12 {
            if bytes[j] == b'\'' {
                return Some(j);
            }
            j += 1;
        }
        return None;
    }
    // Plain char: exactly `'x'`; anything longer is a lifetime.
    if bytes.get(i + 2) == Some(&b'\'') && next != b'\'' {
        return Some(i + 2);
    }
    None
}

/// Parse `siglint:` comments into allow sites / malformed reports.
fn parse_allows(
    code: &str,
    line_starts: &[usize],
    comments: &[(usize, String)],
) -> (Vec<AllowSite>, Vec<BadAllow>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for (start, text) in comments {
        let body = text.trim_start_matches('/').trim();
        let Some(rest) = body.strip_prefix("siglint:") else {
            continue;
        };
        let line = match line_starts.binary_search(start) {
            Ok(i) => i + 1,
            Err(i) => i,
        };
        let rest = rest.trim();
        let Some(args) = rest.strip_prefix("allow(") else {
            bad.push(BadAllow {
                line,
                message: format!("expected `allow(<rule>) -- <reason>`, got `{rest}`"),
            });
            continue;
        };
        let Some(close) = args.find(')') else {
            bad.push(BadAllow {
                line,
                message: "unclosed `allow(` annotation".to_string(),
            });
            continue;
        };
        let rule = args[..close].trim().to_string();
        let tail = args[close + 1..].trim();
        let Some(reason) = tail.strip_prefix("--") else {
            bad.push(BadAllow {
                line,
                message: format!("allow({rule}) is missing a `-- <reason>` justification"),
            });
            continue;
        };
        let reason = reason.trim().to_string();
        if reason.is_empty() {
            bad.push(BadAllow {
                line,
                message: format!("allow({rule}) has an empty reason"),
            });
            continue;
        }
        // Trailing comment suppresses its own line; a standalone comment
        // suppresses the next line with code (comments scrub to blanks, so
        // stacked comment lines are skipped naturally).
        let lstart = line_starts.get(line - 1).copied().unwrap_or(0);
        let own_line_code = code[lstart..*start].trim();
        let target_line = if !own_line_code.is_empty() {
            line
        } else {
            next_code_line(code, line_starts, line)
        };
        allows.push(AllowSite {
            rule,
            reason,
            target_line,
            comment_line: line,
        });
    }
    (allows, bad)
}

/// First line after `line` (1-based) with non-blank scrubbed content; falls
/// back to `line` at end of file.
fn next_code_line(code: &str, line_starts: &[usize], line: usize) -> usize {
    let mut l = line + 1;
    while let Some(&start) = line_starts.get(l - 1) {
        let end = line_starts.get(l).copied().unwrap_or(code.len());
        if !code[start..end].trim().is_empty() {
            return l;
        }
        l += 1;
    }
    line
}

/// Spans of `#[cfg(test)]` and `#[test]` items: from the attribute to the
/// close of the following brace block.
fn find_test_spans(code: &str) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    for marker in ["#[cfg(test)]", "#[test]"] {
        let mut from = 0;
        while let Some(pos) = code[from..].find(marker) {
            let at = from + pos;
            from = at + marker.len();
            if let Some(end) = item_end(code, at + marker.len()) {
                spans.push((at, end));
            }
        }
    }
    spans.sort_unstable();
    spans
}

/// End of the item starting after an attribute: the matching `}` of the
/// first `{` encountered (skipping nested attribute brackets).
fn item_end(code: &str, start: usize) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut i = start;
    while i < bytes.len() && bytes[i] != b'{' {
        if bytes[i] == b';' {
            return Some(i + 1); // e.g. a test-gated `use` or macro line
        }
        i += 1;
    }
    let mut depth = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'{' => depth += 1,
            b'}' => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return Some(i + 1);
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}
