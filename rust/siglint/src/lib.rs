//! siglint — repo-invariant static checker for pysiglib.
//!
//! A zero-dependency lint pass over `rust/src`, `rust/tests` and
//! `rust/benches`: a scrubbing lexer blanks comments and literals (byte
//! offsets preserved), then each named rule scans for the tokens it bans or
//! requires. Findings are suppressible line-by-line with
//!
//! ```text
//! // siglint: allow(<rule>) -- <reason>
//! ```
//!
//! where the reason is mandatory and an allow that suppresses nothing is
//! itself a finding (`unused_allow`), as is a malformed annotation
//! (`allow_syntax`). Run as `cargo run -p siglint` from `rust/`; exit code
//! 0 means the tree is clean.
//!
//! The library that siglint checks contains reviewed `unsafe` blocks; this
//! crate forbids them outright, and its `no_unsafe` rule extends the same
//! guarantee to the checked tree's tests and benches, which rustc's
//! per-crate `#![forbid(unsafe_code)]` cannot reach from the library.

#![forbid(unsafe_code)]

pub mod lexer;
pub mod rules;

use std::fmt;
use std::path::Path;

/// One input file: crate-root-relative `/`-separated path plus contents.
pub struct SourceFile {
    pub path: String,
    pub src: String,
}

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Finding {
    pub path: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// The active rules: (name, what it enforces). Allow annotations may only
/// name rules from this table; the `allow_syntax` / `unused_allow`
/// meta-lints are not suppressible.
pub const RULES: &[(&str, &str)] = &[
    (
        "panic_freedom",
        "no unwrap/expect/panic!/unreachable!/bare indexing on the serving path; no panic \
         macros inside the designated backward entry points",
    ),
    ("hot_path_alloc", "no allocation inside designated hot kernel/engine functions"),
    ("env_discipline", "std::env reads only via the cached accessors in config.rs"),
    ("atomics_hygiene", "every atomic Ordering classified; no Relaxed/strong mixes per cell"),
    ("wire_exhaustive", "every Op variant handled in wire encode, decode and router dispatch"),
    (
        "scheme_exhaustive",
        "every Scheme variant dispatched in the scalar, lane and backward Goursat dispatchers",
    ),
    ("no_unsafe", "tests and benches stay unsafe-free (library unsafe is reviewed in-tree)"),
    (
        "failpoint_release_free",
        "failpoint arming calls live in test code only — fault injection stays unreachable \
         in release builds",
    ),
];

/// Lint a set of files; returns findings sorted by (path, line).
pub fn lint(files: &[SourceFile]) -> Vec<Finding> {
    let scrubbed: Vec<(&SourceFile, lexer::Scrubbed)> =
        files.iter().map(|f| (f, lexer::scrub(&f.src))).collect();

    let mut raw = Vec::new();
    for (f, sc) in &scrubbed {
        let ctx = rules::FileCtx {
            path: &f.path,
            scrubbed: sc,
        };
        rules::panic_freedom(&ctx, &mut raw);
        rules::hot_path_alloc(&ctx, &mut raw);
        rules::env_discipline(&ctx, &mut raw);
        rules::atomics_hygiene(&ctx, &mut raw);
        rules::no_unsafe(&ctx, &mut raw);
        rules::failpoint_release_free(&ctx, &mut raw);
    }
    rules::wire_exhaustive(&scrubbed, &mut raw);
    rules::scheme_exhaustive(&scrubbed, &mut raw);

    // Apply allows: a finding whose (rule, line) matches an allow in its
    // file is suppressed, and the allow is marked used.
    let mut used: Vec<Vec<bool>> = scrubbed
        .iter()
        .map(|(_, sc)| vec![false; sc.allows.len()])
        .collect();
    let mut findings = Vec::new();
    for finding in raw {
        let mut suppressed = false;
        if let Some(idx) = scrubbed.iter().position(|(f, _)| f.path == finding.path) {
            let (_, sc) = &scrubbed[idx];
            for (ai, a) in sc.allows.iter().enumerate() {
                if a.rule == finding.rule && a.target_line == finding.line {
                    if let Some(slot) = used[idx].get_mut(ai) {
                        *slot = true;
                    }
                    suppressed = true;
                }
            }
        }
        if !suppressed {
            findings.push(finding);
        }
    }

    // Meta-lints: malformed annotations, unknown rule names, unused allows.
    for (idx, (f, sc)) in scrubbed.iter().enumerate() {
        for b in &sc.bad_allows {
            findings.push(Finding {
                path: f.path.clone(),
                line: b.line,
                rule: "allow_syntax",
                message: b.message.clone(),
            });
        }
        for (ai, a) in sc.allows.iter().enumerate() {
            if !RULES.iter().any(|(n, _)| *n == a.rule) {
                findings.push(Finding {
                    path: f.path.clone(),
                    line: a.comment_line,
                    rule: "allow_syntax",
                    message: format!("allow({}) names an unknown rule", a.rule),
                });
            } else if !used[idx].get(ai).copied().unwrap_or(true) {
                findings.push(Finding {
                    path: f.path.clone(),
                    line: a.comment_line,
                    rule: "unused_allow",
                    message: format!(
                        "allow({}) suppresses nothing on line {} — remove it",
                        a.rule, a.target_line
                    ),
                });
            }
        }
    }

    findings.sort_by(|a, b| (a.path.as_str(), a.line).cmp(&(b.path.as_str(), b.line)));
    findings
}

/// Collect `.rs` files under `<root>/src`, `<root>/tests`, `<root>/benches`
/// with crate-root-relative `/`-separated paths.
pub fn collect_files(root: &Path) -> std::io::Result<Vec<SourceFile>> {
    let mut out = Vec::new();
    for top in ["src", "tests", "benches"] {
        let dir = root.join(top);
        if dir.is_dir() {
            walk(&dir, top, &mut out)?;
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(out)
}

fn walk(dir: &Path, rel: &str, out: &mut Vec<SourceFile>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let path = entry.path();
        if path.is_dir() {
            walk(&path, &format!("{rel}/{name}"), out)?;
        } else if name.ends_with(".rs") {
            out.push(SourceFile {
                path: format!("{rel}/{name}"),
                src: std::fs::read_to_string(&path)?,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(path: &str, src: &str) -> Vec<Finding> {
        lint(&[SourceFile {
            path: path.to_string(),
            src: src.to_string(),
        }])
    }

    #[test]
    fn scrub_preserves_offsets_and_blanks_literals() {
        let src = "let s = \"unwrap() inside a string\"; // .unwrap() in a comment\n";
        let sc = lexer::scrub(src);
        assert_eq!(sc.code.len(), src.len());
        assert!(!sc.code.contains("unwrap"));
        assert_eq!(sc.line_of(0), 1);
    }

    #[test]
    fn scrub_distinguishes_lifetimes_from_chars() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }\n";
        let sc = lexer::scrub(src);
        assert!(sc.code.contains("'a str"), "lifetime must survive");
        assert!(!sc.code.contains("'x'"), "char literal must be blanked");
    }

    #[test]
    fn raw_strings_and_nested_comments_are_blanked() {
        let src = "let r = r#\"panic!(\"no\")\"#; /* outer /* panic! */ still comment */ let x = 1;\n";
        let sc = lexer::scrub(src);
        assert!(!sc.code.contains("panic"));
        assert!(sc.code.contains("let x = 1;"));
    }

    #[test]
    fn trailing_allow_suppresses_its_own_line() {
        let f = one(
            "src/coordinator/demo.rs",
            "fn f(x: Option<u32>) -> u32 {\n    x.unwrap() // siglint: allow(panic_freedom) -- demo\n}\n",
        );
        assert!(f.is_empty(), "expected clean, got {f:?}");
    }

    #[test]
    fn standalone_allow_suppresses_next_code_line() {
        let f = one(
            "src/coordinator/demo.rs",
            "fn f(x: Option<u32>) -> u32 {\n    // siglint: allow(panic_freedom) -- demo\n    // (another comment line in between)\n    x.unwrap()\n}\n",
        );
        assert!(f.is_empty(), "expected clean, got {f:?}");
    }

    #[test]
    fn allow_without_reason_is_a_finding() {
        let f = one(
            "src/coordinator/demo.rs",
            "// siglint: allow(panic_freedom)\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        );
        assert!(f.iter().any(|x| x.rule == "allow_syntax"), "{f:?}");
        // The unwrap itself is still reported: a reasonless allow suppresses
        // nothing.
        assert!(f.iter().any(|x| x.rule == "panic_freedom"), "{f:?}");
    }

    #[test]
    fn allow_for_unknown_rule_is_a_finding() {
        let f = one(
            "src/lib.rs",
            "// siglint: allow(no_such_rule) -- because\nfn f() {}\n",
        );
        assert!(f.iter().any(|x| x.rule == "allow_syntax"), "{f:?}");
    }

    #[test]
    fn unused_allow_is_a_finding() {
        let f = one(
            "src/coordinator/demo.rs",
            "// siglint: allow(panic_freedom) -- nothing here actually panics\nfn f() -> u32 { 7 }\n",
        );
        assert!(f.iter().any(|x| x.rule == "unused_allow"), "{f:?}");
    }

    #[test]
    fn test_code_is_exempt_from_panic_freedom() {
        let f = one(
            "src/coordinator/demo.rs",
            "fn ok() -> u32 { 7 }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n",
        );
        assert!(f.is_empty(), "expected clean, got {f:?}");
    }

    #[test]
    fn slice_type_is_not_indexing() {
        let f = one(
            "src/coordinator/demo.rs",
            "fn f(x: &mut [f64], y: &[u8]) -> usize { x.len() + y.len() }\n",
        );
        assert!(f.is_empty(), "expected clean, got {f:?}");
    }

    #[test]
    fn unwrap_or_is_not_unwrap() {
        let f = one(
            "src/coordinator/demo.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\nfn g(r: Result<u32, String>) -> u32 { r.unwrap_or_else(|_| 1) }\n",
        );
        assert!(f.is_empty(), "expected clean, got {f:?}");
    }
}
