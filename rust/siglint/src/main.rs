//! CLI: lint the pysiglib tree and exit non-zero on findings.
//!
//! Usage: `cargo run -p siglint [--] [crate-root]`. The default root is the
//! parent of this crate's manifest directory, i.e. `rust/`.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(".."));
    let files = match siglint::collect_files(&root) {
        Ok(files) => files,
        Err(e) => {
            eprintln!("siglint: cannot read {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    if files.is_empty() {
        eprintln!(
            "siglint: no .rs files under {} (expected src/, tests/, benches/)",
            root.display()
        );
        return ExitCode::FAILURE;
    }
    let findings = siglint::lint(&files);
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!(
            "siglint: clean — {} files checked against {} rules",
            files.len(),
            siglint::RULES.len()
        );
        ExitCode::SUCCESS
    } else {
        println!("siglint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}
