//! The rules. Each is a named, individually-testable check over scrubbed
//! source (see [`crate::lexer`]); findings carry the rule name so the
//! `// siglint: allow(<rule>) -- <reason>` escape hatch can suppress them
//! line by line.

use crate::lexer::Scrubbed;
use crate::{Finding, SourceFile};

/// Per-file context handed to rules.
pub struct FileCtx<'a> {
    /// Path relative to the crate root, `/`-separated (e.g.
    /// `src/coordinator/wire.rs`).
    pub path: &'a str,
    pub scrubbed: &'a Scrubbed,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offsets of `needle` in `code` with non-ident bytes on both sides.
fn ident_positions(code: &str, needle: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(needle) {
        let at = from + pos;
        from = at + 1;
        let before_ok = at == 0 || !is_ident(bytes[at - 1]);
        let after = at + needle.len();
        let after_ok = after >= bytes.len() || !is_ident(bytes[after]);
        if before_ok && after_ok {
            out.push(at);
        }
    }
    out
}

/// Next non-whitespace byte at or after `i`.
fn next_nonspace(bytes: &[u8], mut i: usize) -> Option<(usize, u8)> {
    while i < bytes.len() {
        if !bytes[i].is_ascii_whitespace() {
            return Some((i, bytes[i]));
        }
        i += 1;
    }
    None
}

/// Previous non-whitespace byte strictly before `i`.
fn prev_nonspace(bytes: &[u8], i: usize) -> Option<(usize, u8)> {
    let mut j = i;
    while j > 0 {
        j -= 1;
        if !bytes[j].is_ascii_whitespace() {
            return Some((j, bytes[j]));
        }
    }
    None
}

/// The word (maximal ident run) ending at byte `end` inclusive.
fn word_ending_at(bytes: &[u8], end: usize) -> &[u8] {
    let mut s = end + 1;
    while s > 0 && is_ident(bytes[s - 1]) {
        s -= 1;
    }
    &bytes[s..end + 1]
}

/// Method-call sites: ident `name` preceded by `.` and followed by `(`.
fn method_calls(code: &str, name: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    ident_positions(code, name)
        .into_iter()
        .filter(|&at| {
            let dot = matches!(prev_nonspace(bytes, at), Some((_, b'.')));
            let call = matches!(next_nonspace(bytes, at + name.len()), Some((_, b'(')));
            dot && call
        })
        .collect()
}

/// Macro invocation sites: ident `name` immediately followed by `!`.
fn macro_calls(code: &str, name: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    ident_positions(code, name)
        .into_iter()
        .filter(|&at| bytes.get(at + name.len()) == Some(&b'!'))
        .collect()
}

// ---------------------------------------------------------------------------
// Rule: panic_freedom
// ---------------------------------------------------------------------------

/// Files on the serving request path that must not contain a reachable
/// panic in non-test code.
fn panic_scope(path: &str) -> bool {
    path.starts_with("src/coordinator/")
        || path == "src/corpus/registry.rs"
        || path == "src/corpus/stream.rs"
        || path == "src/corpus/persist.rs"
        || path == "src/kernel/border.rs"
}

/// (file, functions) that must stay free of `unwrap`/`expect`/`panic!`/
/// `unreachable!` even though their files host deliberately-panicking pub
/// wrappers (`gram_vjp`, `mmd2`, ...). These are the backward entry points:
/// validation is hoisted before the thread scopes, so any panic macro inside
/// one of them is a missed error path, not a checked invariant. Bare
/// indexing is allowed here — kernel bodies index against dims validated at
/// the boundary, which the whole-file scope above never has to.
const PANIC_FREE_FNS: &[(&str, &[&str])] = &[
    (
        "src/kernel/gram.rs",
        &[
            "gram_vjp_with_lanes",
            "gram_vjp_sym_with_lanes",
            "try_gram_vjp",
            "try_gram_vjp_with_lanes",
        ],
    ),
    (
        "src/engine/mod.rs",
        &["vjp_kernel", "vjp_gram", "vjp_mmd2", "vjp_mmd2_unbiased"],
    ),
];

/// Keywords that can legally precede `[` without it being an index
/// expression (`&mut [f64]`, `as [u8; 4]`, `for x in [..]`, ...).
const NON_INDEX_WORDS: &[&[u8]] = &[
    b"mut", b"ref", b"dyn", b"as", b"in", b"return", b"break", b"if", b"else", b"match", b"move",
    b"let", b"const", b"static", b"impl", b"for", b"while", b"loop", b"where", b"unsafe", b"await",
    b"yield", b"use", b"pub", b"fn", b"enum", b"struct", b"trait", b"type", b"mod", b"crate",
    b"box", b"continue",
];

/// `[` positions that look like index expressions: the previous non-space
/// byte ends an ident (that is not a keyword) or is `)` / `]`.
fn index_sites(code: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    for (at, b) in bytes.iter().enumerate() {
        if *b != b'[' {
            continue;
        }
        let Some((p, pb)) = prev_nonspace(bytes, at) else {
            continue;
        };
        let indexable = if pb == b')' || pb == b']' {
            true
        } else if is_ident(pb) {
            !NON_INDEX_WORDS.contains(&word_ending_at(bytes, p))
        } else {
            false
        };
        if indexable {
            out.push(at);
        }
    }
    out
}

/// No `unwrap`/`expect`/`panic!`/`unreachable!`/bare slice indexing in
/// non-test code on the serving request path, and no panic macros inside the
/// designated backward entry points ([`PANIC_FREE_FNS`]).
pub fn panic_freedom(ctx: &FileCtx<'_>, findings: &mut Vec<Finding>) {
    let sc = ctx.scrubbed;
    if panic_scope(ctx.path) {
        let mut push = |at: usize, what: &str| {
            if !sc.in_test(at) {
                findings.push(Finding {
                    path: ctx.path.to_string(),
                    line: sc.line_of(at),
                    rule: "panic_freedom",
                    message: format!(
                        "{what} on the request path — return a typed SigError instead"
                    ),
                });
            }
        };
        for at in method_calls(&sc.code, "unwrap") {
            push(at, "`.unwrap()`");
        }
        for at in method_calls(&sc.code, "expect") {
            push(at, "`.expect()`");
        }
        for at in macro_calls(&sc.code, "panic") {
            push(at, "`panic!`");
        }
        for at in macro_calls(&sc.code, "unreachable") {
            push(at, "`unreachable!`");
        }
        for at in index_sites(&sc.code) {
            push(at, "bare slice/array indexing");
        }
    }
    let Some((_, fns)) = PANIC_FREE_FNS.iter().find(|(p, _)| *p == ctx.path) else {
        return;
    };
    for name in *fns {
        let Some((start, end)) = fn_body(&sc.code, name) else {
            findings.push(Finding {
                path: ctx.path.to_string(),
                line: 1,
                rule: "panic_freedom",
                message: format!(
                    "panic-free function `{name}` not found — update PANIC_FREE_FNS in siglint"
                ),
            });
            continue;
        };
        let body = &sc.code[start..end];
        let mut push = |at: usize, what: &str| {
            if !sc.in_test(start + at) {
                findings.push(Finding {
                    path: ctx.path.to_string(),
                    line: sc.line_of(start + at),
                    rule: "panic_freedom",
                    message: format!(
                        "{what} inside `{name}` — backward entry points plumb SigError, \
                         they never panic"
                    ),
                });
            }
        };
        for at in method_calls(body, "unwrap") {
            push(at, "`.unwrap()`");
        }
        for at in method_calls(body, "expect") {
            push(at, "`.expect()`");
        }
        for at in macro_calls(body, "panic") {
            push(at, "`panic!`");
        }
        for at in macro_calls(body, "unreachable") {
            push(at, "`unreachable!`");
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: hot_path_alloc
// ---------------------------------------------------------------------------

/// (file, functions) whose bodies form the zero-allocation steady state:
/// the lane sweeps, the `_into` solver variants, and the engine's Gram row
/// strips. The static twin of the workspace arena's runtime assertion.
const HOT_FNS: &[(&str, &[&str])] = &[
    (
        "src/kernel/lanes.rs",
        &[
            "solve_pde_lanes",
            "solve_pde_lanes_scheme",
            "delta_block_lanes",
            "solve_gram_row",
            "solve_group_into",
            "scalar_entry",
            "solve_pde_grid_lanes",
            "vjp_pde_lanes",
            "vjp_pde_lanes_acc",
            "grad_block_lanes",
            "vjp_gram_row",
            "vjp_group_into",
            "scalar_vjp_entry",
        ],
    ),
    (
        "src/kernel/solver.rs",
        &["solve_pde_with", "solve_pde_scheme", "solve_pde_grid_into"],
    ),
    ("src/kernel/backward.rs", &["sig_kernel_vjp_delta_into", "sig_kernel_vjp_delta_acc"]),
    ("src/kernel/delta.rs", &["delta_vjp_to_paths_with"]),
    ("src/engine/mod.rs", &["gram_values_into"]),
];

/// Body span of `fn name` (from its `{` to the matching `}`), if present.
fn fn_body(code: &str, name: &str) -> Option<(usize, usize)> {
    let bytes = code.as_bytes();
    for at in ident_positions(code, name) {
        // Require the `fn` keyword shortly before (skipping generics is not
        // needed: the name directly follows `fn `).
        let Some((p, _)) = prev_nonspace(bytes, at) else {
            continue;
        };
        if p < 1 || word_ending_at(bytes, p) != b"fn" {
            continue;
        }
        // Find the opening brace at angle/paren depth 0.
        // `[` counts too: `[f64; W]` in a signature must not read as the
        // `;` of a bodyless declaration.
        let mut i = at + name.len();
        let mut paren = 0i32;
        let mut angle = 0i32;
        while i < bytes.len() {
            match bytes[i] {
                b'(' | b'[' => paren += 1,
                b')' | b']' => paren -= 1,
                b'<' => angle += 1,
                b'>' if angle > 0 => angle -= 1,
                b'{' if paren == 0 => break,
                b';' if paren == 0 => break,
                _ => {}
            }
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'{' {
            continue;
        }
        let start = i;
        let mut depth = 0usize;
        while i < bytes.len() {
            match bytes[i] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        return Some((start, i + 1));
                    }
                }
                _ => {}
            }
            i += 1;
        }
    }
    None
}

/// No allocation (`Vec::new`/`vec!`/`to_vec`/`collect`/`Box::new`/`clone`)
/// inside the designated hot functions.
pub fn hot_path_alloc(ctx: &FileCtx<'_>, findings: &mut Vec<Finding>) {
    let Some((_, fns)) = HOT_FNS.iter().find(|(p, _)| *p == ctx.path) else {
        return;
    };
    let sc = ctx.scrubbed;
    for name in *fns {
        let Some((start, end)) = fn_body(&sc.code, name) else {
            findings.push(Finding {
                path: ctx.path.to_string(),
                line: 1,
                rule: "hot_path_alloc",
                message: format!(
                    "hot function `{name}` not found — update the HOT_FNS table in siglint"
                ),
            });
            continue;
        };
        let body = &sc.code[start..end];
        let mut push = |off: usize, what: &str| {
            findings.push(Finding {
                path: ctx.path.to_string(),
                line: sc.line_of(start + off),
                rule: "hot_path_alloc",
                message: format!("{what} inside hot function `{name}` — use the workspace arena"),
            });
        };
        for at in ident_positions(body, "Vec") {
            if body[at..].starts_with("Vec::new") || body[at..].starts_with("Vec :: new") {
                push(at, "`Vec::new`");
            }
        }
        for at in ident_positions(body, "Box") {
            if body[at..].starts_with("Box::new") || body[at..].starts_with("Box :: new") {
                push(at, "`Box::new`");
            }
        }
        for at in macro_calls(body, "vec") {
            push(at, "`vec!`");
        }
        for at in method_calls(body, "to_vec") {
            push(at, "`.to_vec()`");
        }
        for at in method_calls(body, "clone") {
            push(at, "`.clone()`");
        }
        for at in ident_positions(body, "collect") {
            // `.collect()` or `.collect::<..>()`.
            let bytes = body.as_bytes();
            let dot = matches!(prev_nonspace(bytes, at), Some((_, b'.')));
            let next = next_nonspace(bytes, at + "collect".len()).map(|(_, b)| b);
            if dot && matches!(next, Some(b'(') | Some(b':')) {
                push(at, "`.collect()`");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: env_discipline
// ---------------------------------------------------------------------------

/// `std::env::var` only in `src/config.rs` — every runtime knob goes
/// through the read-once cached accessors there.
pub fn env_discipline(ctx: &FileCtx<'_>, findings: &mut Vec<Finding>) {
    if ctx.path == "src/config.rs" {
        return;
    }
    let sc = ctx.scrubbed;
    for needle in ["env::var", "env::vars", "env::set_var", "env::remove_var"] {
        let mut from = 0;
        while let Some(pos) = sc.code[from..].find(needle) {
            let at = from + pos;
            from = at + needle.len();
            let bytes = sc.code.as_bytes();
            let after = at + needle.len();
            if after < bytes.len() && is_ident(bytes[after]) {
                continue; // e.g. `env::vars` matched inside `env::vars_os`
            }
            findings.push(Finding {
                path: ctx.path.to_string(),
                line: sc.line_of(at),
                rule: "env_discipline",
                message: format!(
                    "`{needle}` outside config.rs — use the read-once accessors in \
                     `config::env` (or `pool::set_thread_override` in tests/benches)"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: atomics_hygiene
// ---------------------------------------------------------------------------

const ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// `std::cmp::Ordering` variants — same type name, nothing to do with
/// atomics; skipped silently.
const CMP_ORDERINGS: &[&str] = &["Less", "Equal", "Greater"];

/// Methods that legitimately take two orderings of different strengths.
const MIXED_OK_METHODS: &[&str] = &["compare_exchange", "compare_exchange_weak", "fetch_update"];

/// One `Ordering::X` use: receiver chain, method, variant.
struct OrderingUse {
    receiver: String,
    method: String,
    variant: &'static str,
    offset: usize,
}

/// Extract the call context of each `Ordering::` use in a file.
fn ordering_uses(code: &str) -> (Vec<OrderingUse>, Vec<usize>) {
    let bytes = code.as_bytes();
    let mut uses = Vec::new();
    let mut unknown = Vec::new();
    for at in ident_positions(code, "Ordering") {
        let rest = &code[at..];
        if !rest[8..].starts_with("::") {
            continue;
        }
        let Some(variant) = ORDERINGS
            .iter()
            .find(|v| {
                rest[10..].starts_with(**v)
                    && !bytes.get(at + 10 + v.len()).copied().is_some_and(is_ident)
            })
            .copied()
        else {
            if !CMP_ORDERINGS.iter().any(|v| rest[10..].starts_with(*v)) {
                unknown.push(at);
            }
            continue;
        };
        // Walk back to the call's opening paren at reverse depth 0, then
        // the method ident, then the receiver chain.
        let mut depth = 0i32;
        let mut j = at;
        let mut open = None;
        while j > 0 {
            j -= 1;
            match bytes[j] {
                b')' | b']' => depth += 1,
                b'(' | b'[' => {
                    if depth == 0 {
                        open = Some(j);
                        break;
                    }
                    depth -= 1;
                }
                b';' | b'{' | b'}' => break,
                _ => {}
            }
        }
        let Some(open) = open else { continue };
        let Some((m_end, mb)) = prev_nonspace(bytes, open) else {
            continue;
        };
        if !is_ident(mb) {
            continue;
        }
        let method = String::from_utf8_lossy(word_ending_at(bytes, m_end)).into_owned();
        let m_start = m_end + 1 - method.len();
        let receiver = match prev_nonspace(bytes, m_start) {
            Some((d, b'.')) => {
                let mut s = d;
                while s > 0 {
                    let c = bytes[s - 1];
                    if is_ident(c) || c == b'.' || c == b':' {
                        s -= 1;
                    } else {
                        break;
                    }
                }
                code[s..d].trim().to_string()
            }
            _ => String::new(),
        };
        uses.push(OrderingUse {
            receiver,
            method,
            variant,
            offset: at,
        });
    }
    (uses, unknown)
}

/// Every `Ordering::` use classified; a receiver that mixes `Relaxed` with
/// a stronger ordering (outside compare-exchange-style calls) is flagged —
/// a monotone counter and a control flag must not share a cell.
pub fn atomics_hygiene(ctx: &FileCtx<'_>, findings: &mut Vec<Finding>) {
    let sc = ctx.scrubbed;
    let (uses, unknown) = ordering_uses(&sc.code);
    for at in unknown {
        findings.push(Finding {
            path: ctx.path.to_string(),
            line: sc.line_of(at),
            rule: "atomics_hygiene",
            message: "unrecognised `Ordering::` variant — siglint cannot classify it".to_string(),
        });
    }
    // Group by receiver — (recv, saw_relaxed, saw_strong, first_offset) —
    // and flag receivers that mix Relaxed with stronger orderings.
    let mut receivers: Vec<(&str, bool, bool, usize)> = Vec::new();
    for u in &uses {
        if u.receiver.is_empty() || MIXED_OK_METHODS.contains(&u.method.as_str()) {
            continue;
        }
        let relaxed = u.variant == "Relaxed";
        match receivers.iter_mut().find(|(r, ..)| *r == u.receiver) {
            Some(entry) => {
                entry.1 |= relaxed;
                entry.2 |= !relaxed;
            }
            None => receivers.push((&u.receiver, relaxed, !relaxed, u.offset)),
        }
    }
    for (recv, relaxed, strong, offset) in receivers {
        if relaxed && strong {
            findings.push(Finding {
                path: ctx.path.to_string(),
                line: sc.line_of(offset),
                rule: "atomics_hygiene",
                message: format!(
                    "`{recv}` mixes Relaxed with stronger orderings — counters are \
                     Relaxed, control flags are SeqCst/Acquire-Release, never both"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: wire_exhaustive (cross-file)
// ---------------------------------------------------------------------------

/// Variant names of `enum Op` in `src/coordinator/mod.rs`.
fn op_variants(code: &str) -> Option<Vec<String>> {
    enum_variants(code, "Op")
}

/// Variant names of `enum <name>`, if declared in `code`.
fn enum_variants(code: &str, name: &str) -> Option<Vec<String>> {
    let pat = format!("enum {name}");
    let at = code.find(&pat)?;
    let bytes = code.as_bytes();
    // Reject a longer ident (e.g. `enum Options` when looking for `Op`).
    if bytes.get(at + pat.len()).copied().is_some_and(is_ident) {
        return None;
    }
    let open = at + code[at..].find('{')?;
    let mut depth = 0usize;
    let mut end = open;
    for (o, b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    end = o;
                    break;
                }
            }
            _ => {}
        }
    }
    let body = &code[open + 1..end];
    let mut variants = Vec::new();
    let mut piece = String::new();
    let mut depth = 0i32;
    for c in body.chars() {
        match c {
            '{' | '(' | '<' => {
                depth += 1;
                piece.push(c);
            }
            '}' | ')' | '>' => {
                depth -= 1;
                piece.push(c);
            }
            ',' if depth == 0 => {
                if let Some(name) = leading_ident(&piece) {
                    variants.push(name);
                }
                piece.clear();
            }
            _ => piece.push(c),
        }
    }
    if let Some(name) = leading_ident(&piece) {
        variants.push(name);
    }
    Some(variants)
}

/// First ident in a variant body, skipping whitespace and `#[...]`
/// attributes (`Scheme::Order1` is `#[default]`; doc comments are already
/// blanked by the scrubber).
fn leading_ident(piece: &str) -> Option<String> {
    let mut t = piece.trim_start();
    while let Some(rest) = t.strip_prefix('#') {
        let inner = rest.trim_start().strip_prefix('[')?;
        let close = inner.find(']')?;
        t = inner[close + 1..].trim_start();
    }
    let end = t.bytes().position(|b| !is_ident(b)).unwrap_or(t.len());
    if end == 0 {
        return None;
    }
    Some(t[..end].to_string())
}

/// Declared value of `const OP_CODE_COUNT: usize = N;`, if the constant is
/// present (fixture trios may omit it).
fn op_code_count(code: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    for at in ident_positions(code, "OP_CODE_COUNT") {
        let is_decl = prev_nonspace(bytes, at)
            .is_some_and(|(p, b)| is_ident(b) && word_ending_at(bytes, p) == b"const");
        if !is_decl {
            continue;
        }
        let rest = &code[at..];
        let (Some(eq), Some(semi)) = (rest.find('='), rest.find(';')) else {
            continue;
        };
        if semi < eq {
            continue;
        }
        return rest[eq + 1..semi].trim().parse().ok();
    }
    None
}

/// Non-test prefix of a file (everything before the first test span).
fn non_test_code(sc: &Scrubbed) -> String {
    let mut out = String::with_capacity(sc.code.len());
    let mut pos = 0;
    for &(s, e) in &sc.test_spans {
        if s > pos {
            out.push_str(&sc.code[pos..s]);
        }
        pos = pos.max(e);
    }
    if pos < sc.code.len() {
        out.push_str(&sc.code[pos..]);
    }
    out
}

/// Every `Op::` variant must appear in the wire encoder (`op_to_parts`),
/// the wire decoder (`op_from_parts`), the code map (`Op::code`, when
/// present), and the router's non-test dispatch; and when the enum declares
/// `OP_CODE_COUNT` (the per-op metrics array length) it must equal the
/// variant count — op-code drift is a lint failure, not a prod 500.
pub fn wire_exhaustive(files: &[(&SourceFile, Scrubbed)], findings: &mut Vec<Finding>) {
    let find = |path: &str| files.iter().find(|(f, _)| f.path == path);
    let Some((_, mod_sc)) = find("src/coordinator/mod.rs") else {
        return; // single-file fixture runs: nothing to check
    };
    let Some(variants) = op_variants(&mod_sc.code) else {
        return;
    };
    let Some((_, wire_sc)) = find("src/coordinator/wire.rs") else {
        return;
    };
    let Some((_, router_sc)) = find("src/coordinator/router.rs") else {
        return;
    };
    // Codes are 1-based and dense, so the declared count and the variant
    // count must agree — a new variant without the bump silently truncates
    // the per-op metrics array.
    if let Some(n) = op_code_count(&non_test_code(mod_sc)) {
        if n != variants.len() {
            findings.push(Finding {
                path: "src/coordinator/mod.rs".to_string(),
                line: 1,
                rule: "wire_exhaustive",
                message: format!(
                    "OP_CODE_COUNT = {n} but `enum Op` declares {} variants — \
                     codes are 1-based and dense",
                    variants.len()
                ),
            });
        }
    }
    let mut sites: Vec<(&str, String)> = vec![
        (
            "encoder `op_to_parts` (src/coordinator/wire.rs)",
            fn_body(&wire_sc.code, "op_to_parts")
                .map(|(s, e)| wire_sc.code[s..e].to_string())
                .unwrap_or_default(),
        ),
        (
            "decoder `op_from_parts` (src/coordinator/wire.rs)",
            fn_body(&wire_sc.code, "op_from_parts")
                .map(|(s, e)| wire_sc.code[s..e].to_string())
                .unwrap_or_default(),
        ),
        (
            "router dispatch (src/coordinator/router.rs)",
            non_test_code(router_sc),
        ),
    ];
    // The code map is a method on Op itself; fixture mods without one are
    // still checkable against the other three sites.
    if let Some((s, e)) = fn_body(&mod_sc.code, "code") {
        sites.push((
            "code map `Op::code` (src/coordinator/mod.rs)",
            mod_sc.code[s..e].to_string(),
        ));
    }
    for v in &variants {
        for (where_, code) in &sites {
            let token = format!("Op::{v}");
            let present = ident_positions(code, &token)
                .iter()
                .any(|&at| code.as_bytes().get(at + token.len()) != Some(&b':'));
            if !present {
                findings.push(Finding {
                    path: "src/coordinator/mod.rs".to_string(),
                    line: 1,
                    rule: "wire_exhaustive",
                    message: format!("`Op::{v}` is not handled in the {where_}"),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: scheme_exhaustive (cross-file)
// ---------------------------------------------------------------------------

/// Every `Scheme` variant must stay dispatched in the three solver entry
/// points that branch on it: the scalar solver (`solve_pde_scheme`), the
/// lane solver (`solve_pde_lanes_scheme`) and the backward pass
/// (`sig_kernel_vjp_delta_scheme_into`). These matches are written
/// exhaustively today, but a `_ =>` fallback added under refactoring
/// pressure would silently route a new variant to the wrong discretisation
/// — so the lint requires the literal `Scheme::<Variant>` token in each
/// dispatcher body rather than trusting rustc's exhaustiveness check.
pub fn scheme_exhaustive(files: &[(&SourceFile, Scrubbed)], findings: &mut Vec<Finding>) {
    let find = |path: &str| files.iter().find(|(f, _)| f.path == path);
    let Some((_, scheme_sc)) = find("src/kernel/scheme.rs") else {
        return; // single-file fixture runs: nothing to check
    };
    let Some(variants) = enum_variants(&scheme_sc.code, "Scheme") else {
        return;
    };
    const DISPATCHERS: &[(&str, &str, &str)] = &[
        ("src/kernel/solver.rs", "solve_pde_scheme", "scalar solver dispatch"),
        ("src/kernel/lanes.rs", "solve_pde_lanes_scheme", "lane dispatch"),
        (
            "src/kernel/backward.rs",
            "sig_kernel_vjp_delta_scheme_into",
            "backward dispatch",
        ),
    ];
    for &(path, fn_name, label) in DISPATCHERS {
        let Some((_, sc)) = find(path) else {
            continue; // fixture sets may carry a subset of the dispatch files
        };
        let Some((s, e)) = fn_body(&sc.code, fn_name) else {
            findings.push(Finding {
                path: path.to_string(),
                line: 1,
                rule: "scheme_exhaustive",
                message: format!(
                    "dispatch function `{fn_name}` not found — update scheme_exhaustive in siglint"
                ),
            });
            continue;
        };
        let body = &sc.code[s..e];
        for v in &variants {
            let token = format!("Scheme::{v}");
            let present = ident_positions(body, &token)
                .iter()
                .any(|&at| body.as_bytes().get(at + token.len()) != Some(&b':'));
            if !present {
                findings.push(Finding {
                    path: path.to_string(),
                    line: sc.line_of(s),
                    rule: "scheme_exhaustive",
                    message: format!(
                        "`Scheme::{v}` is not dispatched in the {label} (`{fn_name}`)"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rule: no_unsafe
// ---------------------------------------------------------------------------

/// `unsafe` is forbidden outside `src/` — the library's `unsafe` blocks are
/// reviewed in-tree; tests and benches extend `#![forbid(unsafe_code)]`.
pub fn no_unsafe(ctx: &FileCtx<'_>, findings: &mut Vec<Finding>) {
    if !ctx.path.starts_with("tests/") && !ctx.path.starts_with("benches/") {
        return;
    }
    let sc = ctx.scrubbed;
    for at in ident_positions(&sc.code, "unsafe") {
        findings.push(Finding {
            path: ctx.path.to_string(),
            line: sc.line_of(at),
            rule: "no_unsafe",
            message: "`unsafe` in tests/benches — keep unsafety inside the library".to_string(),
        });
    }
}

// ---------------------------------------------------------------------------
// Rule: failpoint_release_free
// ---------------------------------------------------------------------------

/// Failpoint *arming* (`failpoint::arm` / `failpoint::arm_times`) is a test
/// facility: armed sites change control flow, so an arming call reachable
/// from non-test code would let fault injection fire in production. The
/// `failpoint!` macro and `failpoint::eval` stay legal everywhere — they are
/// inert unless something arms them. The facility's own module is exempt
/// (it defines `arm`), as are integration tests under `tests/`.
pub fn failpoint_release_free(ctx: &FileCtx<'_>, findings: &mut Vec<Finding>) {
    if ctx.path == "src/util/failpoint.rs" || ctx.path.starts_with("tests/") {
        return;
    }
    let sc = ctx.scrubbed;
    for (at, _) in sc.code.match_indices("failpoint::arm") {
        if !sc.in_test(at) {
            findings.push(Finding {
                path: ctx.path.to_string(),
                line: sc.line_of(at),
                rule: "failpoint_release_free",
                message: "failpoint arming outside test code — fault injection must stay \
                          unreachable in release builds"
                    .to_string(),
            });
        }
    }
}
