//! Fixture tests (each known-bad snippet trips exactly its own rule) plus
//! the real gate: the pysiglib tree at `../` must lint clean.

use siglint::{collect_files, lint, Finding, SourceFile};

fn one(path: &str, src: &str) -> Vec<Finding> {
    lint(&[SourceFile {
        path: path.to_string(),
        src: src.to_string(),
    }])
}

fn only_rule(findings: &[Finding], rule: &str) {
    assert!(!findings.is_empty(), "fixture for {rule} tripped nothing");
    for f in findings {
        assert_eq!(f.rule, rule, "unexpected finding {f}");
    }
}

#[test]
fn panic_freedom_fixture() {
    let f = one(
        "src/coordinator/fixture.rs",
        include_str!("fixtures/panic_freedom.rs"),
    );
    only_rule(&f, "panic_freedom");
    // Bare indexing + unwrap; the #[cfg(test)] unwrap is exempt.
    assert_eq!(f.len(), 2, "{f:?}");
}

#[test]
fn hot_path_alloc_fixture() {
    let f = one(
        "src/kernel/solver.rs",
        include_str!("fixtures/hot_path_alloc.rs"),
    );
    only_rule(&f, "hot_path_alloc");
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].message.contains("to_vec"), "{f:?}");
}

#[test]
fn env_discipline_fixture() {
    let f = one(
        "src/corpus/tiles.rs",
        include_str!("fixtures/env_discipline.rs"),
    );
    only_rule(&f, "env_discipline");
    assert_eq!(f.len(), 1, "{f:?}");
}

#[test]
fn atomics_hygiene_fixture() {
    let f = one(
        "src/util/pool.rs",
        include_str!("fixtures/atomics_hygiene.rs"),
    );
    only_rule(&f, "atomics_hygiene");
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].message.contains("self.hits"), "{f:?}");
}

#[test]
fn wire_exhaustive_fixture() {
    let files = vec![
        SourceFile {
            path: "src/coordinator/mod.rs".to_string(),
            src: include_str!("fixtures/wire_mod.rs").to_string(),
        },
        SourceFile {
            path: "src/coordinator/wire.rs".to_string(),
            src: include_str!("fixtures/wire_wire.rs").to_string(),
        },
        SourceFile {
            path: "src/coordinator/router.rs".to_string(),
            src: include_str!("fixtures/wire_router.rs").to_string(),
        },
    ];
    let f = lint(&files);
    only_rule(&f, "wire_exhaustive");
    // Mmd2 missing from encoder, decoder and router dispatch.
    assert_eq!(f.len(), 3, "{f:?}");
    for x in &f {
        assert!(x.message.contains("Op::Mmd2"), "{x}");
    }
}

#[test]
fn wire_exhaustive_checks_op_code_count_and_the_code_map() {
    // A mod whose OP_CODE_COUNT lags the variant count and whose code map
    // swallows `B` in a wildcard: two findings on top of an otherwise
    // fully-wired trio.
    let mod_src = "pub const OP_CODE_COUNT: usize = 1;\n\
                   pub enum Op {\n    A,\n    B,\n}\n\
                   impl Op {\n    pub fn code(&self) -> u32 {\n        match self {\n            \
                   Op::A => 1,\n            _ => 2,\n        }\n    }\n}\n";
    let wire_src = "pub fn op_to_parts(op: &Op) -> (u32, u32) {\n    match op {\n        \
                    Op::A => (1, 0),\n        Op::B => (2, 0),\n    }\n}\n\
                    pub fn op_from_parts(code: u32) -> Option<Op> {\n    match code {\n        \
                    1 => Some(Op::A),\n        2 => Some(Op::B),\n        _ => None,\n    }\n}\n";
    let router_src = "pub fn dispatch(op: &Op) -> u32 {\n    match op {\n        \
                      Op::A => 1,\n        Op::B => 2,\n    }\n}\n";
    let files = vec![
        SourceFile {
            path: "src/coordinator/mod.rs".to_string(),
            src: mod_src.to_string(),
        },
        SourceFile {
            path: "src/coordinator/wire.rs".to_string(),
            src: wire_src.to_string(),
        },
        SourceFile {
            path: "src/coordinator/router.rs".to_string(),
            src: router_src.to_string(),
        },
    ];
    let f = lint(&files);
    only_rule(&f, "wire_exhaustive");
    assert_eq!(f.len(), 2, "{f:?}");
    assert!(
        f.iter()
            .any(|x| x.message.contains("OP_CODE_COUNT = 1") && x.message.contains("2 variants")),
        "{f:?}"
    );
    assert!(
        f.iter().any(|x| x.message.contains("Op::B") && x.message.contains("code map")),
        "{f:?}"
    );
}

#[test]
fn scheme_exhaustive_fixture() {
    let files = vec![
        SourceFile {
            path: "src/kernel/scheme.rs".to_string(),
            src: include_str!("fixtures/scheme_enum.rs").to_string(),
        },
        SourceFile {
            path: "src/kernel/solver.rs".to_string(),
            src: include_str!("fixtures/scheme_solver.rs").to_string(),
        },
    ];
    let f = lint(&files);
    only_rule(&f, "scheme_exhaustive");
    // Order3 swallowed by the solver's wildcard arm; the lane and backward
    // dispatch files are absent from the fixture set, which is tolerated.
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].message.contains("Scheme::Order3"), "{f:?}");
    assert!(f[0].message.contains("scalar solver dispatch"), "{f:?}");
}

#[test]
fn a_missing_scheme_dispatcher_is_itself_a_finding() {
    // backward.rs present (its two hot stubs keep hot_path_alloc quiet) but
    // without `sig_kernel_vjp_delta_scheme_into`: the dispatcher table in
    // scheme_exhaustive can never silently rot.
    let backward_src = "pub fn sig_kernel_vjp_delta_into(out: &mut [f64]) {\n    \
                        for v in out.iter_mut() {\n        *v = 0.0;\n    }\n}\n\
                        pub fn sig_kernel_vjp_delta_acc(out: &mut [f64]) {\n    \
                        for v in out.iter_mut() {\n        *v += 1.0;\n    }\n}\n";
    let files = vec![
        SourceFile {
            path: "src/kernel/scheme.rs".to_string(),
            src: "pub enum Scheme {\n    Order1,\n    Order2,\n}\n".to_string(),
        },
        SourceFile {
            path: "src/kernel/backward.rs".to_string(),
            src: backward_src.to_string(),
        },
    ];
    let f = lint(&files);
    only_rule(&f, "scheme_exhaustive");
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].message.contains("sig_kernel_vjp_delta_scheme_into"), "{f:?}");
}

#[test]
fn panic_freedom_guards_the_designated_backward_fns() {
    // An `.expect()` inside a designated backward fn trips the rule; the
    // deliberately-panicking pub wrapper in the same file stays exempt, as
    // does bare indexing (kernel bodies index against validated dims).
    let src = "pub fn gram_vjp_with_lanes(v: &[f64]) -> f64 {\n    \
               v[0] + v.first().copied().expect(\"nonempty\")\n}\n\
               pub fn gram_vjp_sym_with_lanes() {}\n\
               pub fn try_gram_vjp() {}\n\
               pub fn try_gram_vjp_with_lanes() {}\n\
               pub fn gram_vjp(v: &[f64]) -> f64 {\n    \
               v.first().copied().expect(\"wrapper is documented to panic\")\n}\n";
    let f = one("src/kernel/gram.rs", src);
    only_rule(&f, "panic_freedom");
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].message.contains("gram_vjp_with_lanes"), "{f:?}");
}

#[test]
fn a_missing_designated_backward_fn_is_itself_a_finding() {
    // All four engine vjp entry points absent: one finding each, so the
    // PANIC_FREE_FNS table can never silently rot.
    let f = one("src/engine/mod.rs", "pub fn gram_values_into() {}\n");
    only_rule(&f, "panic_freedom");
    assert_eq!(f.len(), 4, "{f:?}");
    assert!(f.iter().all(|x| x.message.contains("PANIC_FREE_FNS")), "{f:?}");
}

#[test]
fn the_streaming_files_are_in_the_panic_freedom_scope() {
    for path in [
        "src/corpus/stream.rs",
        "src/kernel/border.rs",
        "src/corpus/persist.rs",
    ] {
        let f = one(path, "pub fn f(v: &[f64]) -> f64 {\n    v[0]\n}\n");
        only_rule(&f, "panic_freedom");
        assert_eq!(f.len(), 1, "{path}: {f:?}");
    }
}

#[test]
fn failpoint_release_free_fixture() {
    let f = one(
        "src/engine/fault.rs",
        include_str!("fixtures/failpoint_release_free.rs"),
    );
    only_rule(&f, "failpoint_release_free");
    // Only the non-test arming call; `eval` and the in-test arming pass.
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].line, 6, "{f:?}");
}

#[test]
fn the_failpoint_module_itself_may_define_arming() {
    let f = one(
        "src/util/failpoint.rs",
        "pub fn arm(name: &str, v: u64) {\n    super::failpoint::arm_impl(name, v);\n}\n",
    );
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn no_unsafe_fixture() {
    let f = one("tests/fixture.rs", include_str!("fixtures/no_unsafe.rs"));
    only_rule(&f, "no_unsafe");
    assert_eq!(f.len(), 1, "{f:?}");
}

#[test]
fn an_allow_silences_a_fixture_violation_with_reason() {
    let f = one(
        "src/corpus/tiles.rs",
        "pub fn t() -> usize {\n    // siglint: allow(env_discipline) -- fixture demonstrates the escape hatch\n    std::env::var(\"PYSIGLIB_TILE\").map(|v| v.len()).unwrap_or(0)\n}\n",
    );
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn the_pysiglib_tree_lints_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let files = collect_files(&root).expect("reading ../src, ../tests, ../benches");
    assert!(files.len() > 20, "expected the full tree, found {} files", files.len());
    let findings = lint(&files);
    assert!(
        findings.is_empty(),
        "tree has {} finding(s):\n{}",
        findings.len(),
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}
