// Linted as src/kernel/solver.rs: `solve_pde_scheme` routes the new
// `Order3` variant through a wildcard arm — exactly the silent-rot
// scheme_exhaustive exists to catch. The two stubs above it keep the
// HOT_FNS presence check quiet.

pub fn solve_pde_with(x: &[f64]) -> f64 {
    x.iter().sum()
}

pub fn solve_pde_grid_into(out: &mut [f64]) {
    for v in out.iter_mut() {
        *v = 0.0;
    }
}

pub fn solve_pde_scheme(x: &[f64], scheme: Scheme) -> f64 {
    match scheme {
        Scheme::Order1 => solve_pde_with(x),
        Scheme::Order2 => 4.0 / 3.0 * solve_pde_with(x),
        _ => 0.0,
    }
}
