// Known-bad fixture for `hot_path_alloc`: linted as src/kernel/solver.rs.
// One violation (`to_vec` in `solve_pde_with`); `solve_pde_scheme` and
// `solve_pde_grid_into` are present and clean so the HOT_FNS presence
// check stays quiet.

pub fn solve_pde_with(x: &[f64]) -> f64 {
    let copy = x.to_vec();
    copy.iter().sum()
}

pub fn solve_pde_scheme(x: &[f64]) -> f64 {
    solve_pde_with(x)
}

pub fn solve_pde_grid_into(out: &mut [f64]) {
    for v in out.iter_mut() {
        *v = 0.0;
    }
}
