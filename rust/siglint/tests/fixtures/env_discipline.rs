// Known-bad fixture for `env_discipline`: linted as src/corpus/tiles.rs.
// One violation: a raw env read outside config.rs.

pub fn tile_from_env() -> usize {
    std::env::var("PYSIGLIB_TILE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}
