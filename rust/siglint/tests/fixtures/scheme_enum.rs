// Known-bad fixture pair for `scheme_exhaustive`: linted as
// src/kernel/scheme.rs alongside scheme_solver.rs. Declares a third
// variant that the solver fixture's dispatch swallows in a wildcard arm.
// The `#[default]` attribute mirrors the real enum so the variant parser's
// attribute skipping stays covered.

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Scheme {
    #[default]
    Order1,
    Order2,
    Order3,
}
