// Known-bad fixture for `atomics_hygiene`: linted as src/util/pool.rs.
// One violation: `self.hits` is written Relaxed but read SeqCst — a counter
// and a control flag sharing one cell.

use std::sync::atomic::{AtomicUsize, Ordering};

pub struct Counter {
    hits: AtomicUsize,
}

impl Counter {
    pub fn bump(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn read(&self) -> usize {
        self.hits.load(Ordering::SeqCst)
    }
}
