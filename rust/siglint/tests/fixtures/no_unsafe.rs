// Known-bad fixture for `no_unsafe`: linted as tests/fixture.rs.
// One violation: an unsafe block in a test file.

#[test]
fn peeks_past_the_api() {
    let xs = [1u8, 2, 3];
    let first = unsafe { *xs.as_ptr() };
    assert_eq!(first, 1);
}
