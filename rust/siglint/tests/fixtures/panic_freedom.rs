// Known-bad fixture for `panic_freedom`: linted as src/coordinator/fixture.rs.
// Two violations (bare indexing, unwrap); the test-module unwrap is exempt.

pub fn first(values: &[f64]) -> f64 {
    values[0]
}

pub fn must(x: Option<u32>) -> u32 {
    x.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_side_unwrap_is_fine() {
        Some(1).unwrap();
    }
}
