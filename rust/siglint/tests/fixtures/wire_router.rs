// Fixture for `wire_exhaustive`: linted as src/coordinator/router.rs.
// Dispatches Signature and SigKernel but swallows Mmd2 in a wildcard.

use crate::coordinator::Op;

pub fn dispatch(op: &Op) -> &'static str {
    match op {
        Op::Signature { .. } => "signature",
        Op::SigKernel => "kernel",
        _ => "unknown",
    }
}
