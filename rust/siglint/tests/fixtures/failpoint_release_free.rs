// Fixture for `failpoint_release_free`: linted as src/engine/fault.rs.
// Arming a failpoint in non-test code trips the rule; the passive `eval`
// probe and the #[cfg(test)] arming are both exempt.

pub fn warm_up() {
    crate::util::failpoint::arm("snapshot.torn_write", 8);
}

pub fn observe() -> Option<u64> {
    crate::util::failpoint::eval("snapshot.short_read")
}

#[cfg(test)]
mod tests {
    #[test]
    fn arming_in_tests_is_fine() {
        crate::util::failpoint::arm_times("batcher.enqueue_full", 1, 1);
    }
}
