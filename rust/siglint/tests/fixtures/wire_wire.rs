// Fixture for `wire_exhaustive`: linted as src/coordinator/wire.rs.
// Handles Signature and SigKernel but not Mmd2, in both directions.

use crate::coordinator::Op;

pub fn op_to_parts(op: &Op) -> (u32, u32) {
    match op {
        Op::Signature { depth } => (1, *depth),
        Op::SigKernel => (2, 0),
    }
}

pub fn op_from_parts(code: u32, p1: u32) -> Option<Op> {
    match code {
        1 => Some(Op::Signature { depth: p1 }),
        2 => Some(Op::SigKernel),
        _ => None,
    }
}
