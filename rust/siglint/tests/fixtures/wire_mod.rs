// Fixture trio for `wire_exhaustive`: linted as src/coordinator/mod.rs
// together with wire_wire.rs and wire_router.rs. `Op::Mmd2` is missing from
// the encoder, the decoder and the router dispatch — three findings.

pub enum Op {
    Signature { depth: u32 },
    SigKernel,
    Mmd2,
}
