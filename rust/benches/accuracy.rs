//! CI-scale accuracy harness for the Goursat discretisation schemes. All
//! rows are deterministic `record()` entries (runs = 0): relative error of
//! each (scheme, λ) grid against a fine order-1 reference at λ = 6, plus
//! the exact cells-solved count per configuration.
//!
//! `ci/check_accuracy.py` gates the resulting `BENCH_accuracy.json`: the
//! order-2 scheme one dyadic level coarser must stay inside the committed
//! error envelope AND solve strictly fewer cells than order-1 at the fine
//! level — the cost/accuracy claim that justifies shipping the scheme.

use pysiglib::bench::Suite;
use pysiglib::kernel::{delta_matrix, solve_pde_scheme, Scheme};
use pysiglib::transforms::Transform;
use pysiglib::util::rng::Rng;

const PAIRS: usize = 4;
const LEN: usize = 24;
const DIM: usize = 3;
/// Reference grid: order-1 at a dyadic order two levels past the finest
/// measured grid, so the reference's own discretisation error is negligible
/// against every measured row.
const REF_LAMBDA: u32 = 6;
const LAMBDAS: [u32; 4] = [0, 1, 2, 3];

/// PDE cells solved for one pair under (scheme, λ) — the deterministic cost
/// model the gate compares (order-2 adds its half-resolution companion grid
/// except at the degenerate λ = 0, which returns the fine solve directly).
fn cells(scheme: Scheme, lam: u32, m: usize, n: usize) -> usize {
    let fine = (m << lam) * (n << lam);
    match scheme {
        Scheme::Order1 => fine,
        Scheme::Order2 if lam == 0 => fine,
        Scheme::Order2 => fine + (m << (lam - 1)) * (n << (lam - 1)),
    }
}

fn main() {
    let mut suite = Suite::new("accuracy");
    let mut rng = Rng::new(61);
    let deltas: Vec<(usize, usize, Vec<f64>)> = (0..PAIRS)
        .map(|_| {
            let x = rng.brownian_path(LEN, DIM, 0.3);
            let y = rng.brownian_path(LEN, DIM, 0.3);
            delta_matrix(&x, &y, LEN, LEN, DIM, Transform::None)
        })
        .collect();
    let (mut prev, mut cur) = (Vec::new(), Vec::new());
    let refs: Vec<f64> = deltas
        .iter()
        .map(|(m, n, d)| {
            solve_pde_scheme(d, *m, *n, REF_LAMBDA, REF_LAMBDA, Scheme::Order1, &mut prev, &mut cur)
        })
        .collect();

    println!(
        "\n{:<8} {:>8} | {:>12} {:>12} | {:>12} {:>12}",
        "dyadic", "", "err_order1", "err_order2", "cells_o1", "cells_o2"
    );
    for lam in LAMBDAS {
        let mut errs = [0.0f64; 2];
        let mut cell_counts = [0usize; 2];
        for (si, scheme) in [Scheme::Order1, Scheme::Order2].into_iter().enumerate() {
            for (i, (m, n, d)) in deltas.iter().enumerate() {
                let k = solve_pde_scheme(d, *m, *n, lam, lam, scheme, &mut prev, &mut cur);
                let rel = (k - refs[i]).abs() / refs[i].abs().max(1.0);
                errs[si] = errs[si].max(rel);
                cell_counts[si] += cells(scheme, lam, *m, *n);
            }
        }
        println!(
            "{:<8} {:>8} | {:>12.3e} {:>12.3e} | {:>12} {:>12}",
            lam, "", errs[0], errs[1], cell_counts[0], cell_counts[1]
        );
        suite.record(&format!("err_order1_lam{lam}"), errs[0]);
        suite.record(&format!("err_order2_lam{lam}"), errs[1]);
        suite.record(&format!("cells_order1_lam{lam}"), cell_counts[0] as f64);
        suite.record(&format!("cells_order2_lam{lam}"), cell_counts[1] as f64);
    }
    println!(
        "\nreading: err_order2_lam(λ) should sit at or below err_order1_lam(λ+1)\n\
         while cells_order2_lam(λ) stays strictly below cells_order1_lam(λ+1) —\n\
         Richardson extrapolation buys the fine-grid accuracy at a coarser grid's\n\
         cost. ci/check_accuracy.py enforces exactly that pair plus the committed\n\
         error envelopes."
    );
}
