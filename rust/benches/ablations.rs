//! Ablations for the design choices DESIGN.md calls out:
//!   1. Horner (Alg 2) vs direct (Alg 1) vs naive products — same output,
//!      different multiplication counts and memory traffic.
//!   2. On-the-fly dyadic refinement vs materialised refined Δ.
//!   3. Fused (on-the-fly) lead-lag vs materialised lead-lag.
//!   4. Row-sweep vs blocked anti-diagonal solver on CPU.
//!   5. GEMM Δ precompute vs naive per-cell dot products.
//!   6. Batch-parallel scaling over worker threads.

use pysiglib::baselines::{full_grid_kernel, naive_signature};
use pysiglib::bench::{bench_runs, Suite};
use pysiglib::kernel::{
    batch_kernel, delta_matrix, solve_pde, solve_pde_lanes, KernelOptions, SolverKind,
};
use pysiglib::sig::{batch_signature, SigMethod, SigOptions};
use pysiglib::transforms::Transform;
use pysiglib::util::pool::{parallel_for, set_thread_override};
use pysiglib::util::rng::Rng;

fn main() {
    let runs = bench_runs(5);
    let mut suite = Suite::new("ablations");
    let mut rng = Rng::new(51);

    // --- 1. signature algorithm ---
    {
        let (b, l, d, n) = (64, 256, 4, 6);
        let paths = rng.brownian_batch(b, l, d, 0.2);
        suite.time("sig_algo/naive(esig-like)", 1, || {
            parallel_for(b, |i| {
                std::hint::black_box(naive_signature(&paths[i * l * d..(i + 1) * l * d], l, d, n));
            });
        });
        suite.time("sig_algo/direct(alg1)", runs, || {
            std::hint::black_box(batch_signature(
                &paths,
                b,
                l,
                d,
                &SigOptions::new(n).method(SigMethod::Direct),
            ));
        });
        suite.time("sig_algo/horner(alg2)", runs, || {
            std::hint::black_box(batch_signature(&paths, b, l, d, &SigOptions::new(n)));
        });
    }

    // --- 2. dyadic refinement strategy ---
    {
        let (l, d, lam) = (128usize, 4usize, 2u32);
        let x = rng.brownian_path(l, d, 0.1);
        let y = rng.brownian_path(l, d, 0.1);
        let (m, n, delta) = delta_matrix(&x, &y, l, l, d, Transform::None);
        suite.time("dyadic/materialised(fullgrid)", runs, || {
            std::hint::black_box(full_grid_kernel(&delta, m, n, lam, lam).unwrap());
        });
        suite.time("dyadic/on-the-fly(row-sweep)", runs, || {
            std::hint::black_box(solve_pde(&delta, m, n, lam, lam));
        });
    }

    // --- 3. lead-lag: fused vs materialised ---
    {
        let (b, l, d, n) = (64, 256, 3, 4);
        let paths = rng.brownian_batch(b, l, d, 0.2);
        suite.time("leadlag/fused(on-the-fly)", runs, || {
            std::hint::black_box(batch_signature(
                &paths,
                b,
                l,
                d,
                &SigOptions::new(n).transform(Transform::LeadLag),
            ));
        });
        suite.time("leadlag/materialised", runs, || {
            parallel_for(b, |i| {
                let mat = pysiglib::transforms::lead_lag(&paths[i * l * d..(i + 1) * l * d], l, d);
                std::hint::black_box(pysiglib::sig::sig(&mat, 2 * l - 1, 2 * d, n));
            });
        });
    }

    // --- 4. solver schedule ---
    {
        let (b, l, d) = (64, 512, 8);
        let scale = 1.0 / (l as f64).sqrt();
        let xs = rng.brownian_batch(b, l, d, scale);
        let ys = rng.brownian_batch(b, l, d, scale);
        suite.time("solver/row", runs, || {
            std::hint::black_box(batch_kernel(&xs, &ys, b, l, l, d, &KernelOptions::default()));
        });
        suite.time("solver/blocked(gpu-dataflow)", runs, || {
            std::hint::black_box(batch_kernel(
                &xs,
                &ys,
                b,
                l,
                l,
                d,
                &KernelOptions::default().solver(SolverKind::Blocked),
            ));
        });
    }

    // --- 5. Δ precompute: GEMM vs naive ---
    {
        let (l, d) = (1024usize, 32usize);
        let x = rng.brownian_path(l, d, 0.1);
        let y = rng.brownian_path(l, d, 0.1);
        suite.time("delta/gemm", runs, || {
            std::hint::black_box(delta_matrix(&x, &y, l, l, d, Transform::None));
        });
        suite.time("delta/naive-dots", runs, || {
            // per-cell dot products with strided access (what a naive
            // implementation inside the PDE loop would pay)
            let m = l - 1;
            let mut out = vec![0.0; m * m];
            for i in 0..m {
                for j in 0..m {
                    let mut acc = 0.0;
                    for c in 0..d {
                        acc += (x[(i + 1) * d + c] - x[i * d + c])
                            * (y[(j + 1) * d + c] - y[j * d + c]);
                    }
                    out[i * m + j] = acc;
                }
            }
            std::hint::black_box(out);
        });
    }

    // --- 5b. PDE sweep structure: the shipped fused single-pass loop vs
    //         the two-pass restructure that was tried and reverted during
    //         the perf pass (EXPERIMENTS.md §Perf).
    {
        let m = 1023usize;
        let mut delta = vec![0.0; m * m];
        let mut r = Rng::new(77);
        r.fill_normal(&mut delta);
        for v in delta.iter_mut() {
            *v *= 0.001;
        }
        suite.time("pde_sweep/fused-single-pass(shipped)", runs, || {
            std::hint::black_box(solve_pde(&delta, m, m, 0, 0));
        });
        suite.time("pde_sweep/two-pass(tried+reverted)", runs, || {
            std::hint::black_box(solve_pde_two_pass_reference(&delta, m, m));
        });
    }

    // --- 5c. dyadic-run coefficient hoist (shipped in `solve_pde_with`):
    //         p — hence A(p), B(p) — is constant for 2^λ2 consecutive
    //         refined cells, so computing the coefficients once per run
    //         saves 2^λ2−1 of the coefficient FLOPs per cell.
    {
        let (m, lam2) = (255usize, 3u32);
        let mut delta = vec![0.0; m * m];
        let mut r = Rng::new(78);
        r.fill_normal(&mut delta);
        for v in delta.iter_mut() {
            *v *= 0.004;
        }
        suite.time("pde_sweep/dyadic03/run-hoisted(shipped)", runs, || {
            std::hint::black_box(solve_pde(&delta, m, m, 0, lam2));
        });
        suite.time("pde_sweep/dyadic03/per-cell(reference)", runs, || {
            std::hint::black_box(solve_pde_per_cell_reference(&delta, m, m, 0, lam2));
        });
    }

    // --- 5d. lane batching (the shipped across-pair schedule): 8 PDEs per
    //         SoA sweep vs 8 consecutive scalar sweeps on the same Δs.
    {
        const W: usize = 8;
        let m = 511usize;
        let mut r = Rng::new(79);
        let deltas: Vec<Vec<f64>> = (0..W)
            .map(|_| {
                let mut d = vec![0.0; m * m];
                r.fill_normal(&mut d);
                for v in d.iter_mut() {
                    *v *= 0.002;
                }
                d
            })
            .collect();
        let mut block = vec![0.0; m * W * m];
        for (w, d) in deltas.iter().enumerate() {
            for s in 0..m {
                block[(s * W + w) * m..(s * W + w + 1) * m].copy_from_slice(&d[s * m..(s + 1) * m]);
            }
        }
        let (mut prev, mut cur) = (Vec::new(), Vec::new());
        suite.time("pde_sweep/lanes8(shipped)", runs, || {
            std::hint::black_box(solve_pde_lanes::<W>(&block, m, m, 0, 0, &mut prev, &mut cur));
        });
        suite.time("pde_sweep/scalar-x8", runs, || {
            for d in deltas.iter() {
                std::hint::black_box(solve_pde(d, m, m, 0, 0));
            }
        });
    }

    // --- 6. thread scaling ---
    {
        let (b, l, d, n) = (128, 512, 8, 5);
        let paths = rng.brownian_batch(b, l, d, 0.2);
        for threads in [1usize, 2, 4, 8, 0] {
            let label = if threads == 0 {
                "threads/all".to_string()
            } else {
                format!("threads/{threads}")
            };
            // Explicit override, not set_var: env knobs are read once per
            // process and mutating the environment races nothing out of it.
            set_thread_override((threads > 0).then_some(threads));
            suite.time(&label, runs, || {
                std::hint::black_box(batch_signature(&paths, b, l, d, &SigOptions::new(n)));
            });
        }
        set_thread_override(None);
    }

    println!("\nratios:");
    for (a, b_, what) in [
        ("sig_algo/direct(alg1)", "sig_algo/horner(alg2)", "direct/horner"),
        (
            "dyadic/materialised(fullgrid)",
            "dyadic/on-the-fly(row-sweep)",
            "materialised/on-the-fly",
        ),
        ("leadlag/materialised", "leadlag/fused(on-the-fly)", "materialised/fused"),
        ("delta/naive-dots", "delta/gemm", "naive/gemm"),
        (
            "pde_sweep/two-pass(tried+reverted)",
            "pde_sweep/fused-single-pass(shipped)",
            "two-pass/fused-sweep",
        ),
        (
            "pde_sweep/dyadic03/per-cell(reference)",
            "pde_sweep/dyadic03/run-hoisted(shipped)",
            "per-cell/run-hoisted",
        ),
        (
            "pde_sweep/scalar-x8",
            "pde_sweep/lanes8(shipped)",
            "scalar-x8/lanes8",
        ),
        ("threads/1", "threads/all", "1-thread/all-threads"),
    ] {
        if let (Some(x), Some(y)) = (suite.get(a), suite.get(b_)) {
            println!("  {what}: {:.2}x", x / y);
        }
    }
}

/// The §Perf candidate that was tried and *reverted*: split the sweep into
/// a vectorisable pass (prev-row combination) and a minimal serial FMA
/// chain. Kept verbatim so the regression stays measurable (EXPERIMENTS.md
/// §Perf): the extra coefficient/cterm memory traffic costs more than the
/// shorter dependency chain saves on this testbed.
fn solve_pde_two_pass_reference(delta: &[f64], m: usize, n: usize) -> f64 {
    let mut prev = vec![1.0; n + 1];
    let mut cur = vec![1.0; n + 1];
    let mut acoef = vec![0.0; n];
    let mut bcoef = vec![0.0; n];
    let mut cterm = vec![0.0; n];
    for s in 0..m {
        let drow = &delta[s * n..(s + 1) * n];
        for t in 0..n {
            let p = drow[t];
            let p2 = p * p * (1.0 / 12.0);
            acoef[t] = 1.0 + 0.5 * p + p2;
            bcoef[t] = 1.0 - p2;
        }
        for t in 0..n {
            cterm[t] = prev[t + 1] * acoef[t] - prev[t] * bcoef[t];
        }
        let mut k_left = 1.0;
        for t in 0..n {
            k_left = k_left * acoef[t] + cterm[t];
            cur[t + 1] = k_left;
        }
        cur[0] = 1.0;
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[n]
}

/// The historical per-refined-cell coefficient computation (before the
/// dyadic-run hoist shipped in `solve_pde_with`): A(p)/B(p) evaluated for
/// every refined cell even though `t >> λ2` is constant over a run. Kept
/// verbatim so the win stays measurable.
fn solve_pde_per_cell_reference(delta: &[f64], m: usize, n: usize, lam1: u32, lam2: u32) -> f64 {
    let rows = m << lam1;
    let cols = n << lam2;
    let scale = 1.0 / (1u64 << (lam1 + lam2)) as f64;
    let mut prev = vec![1.0; cols + 1];
    let mut cur = vec![1.0; cols + 1];
    for s in 0..rows {
        let drow = &delta[(s >> lam1) * n..(s >> lam1) * n + n];
        cur[0] = 1.0;
        let mut k_left = 1.0;
        for t in 0..cols {
            let p = drow[t >> lam2] * scale;
            let p2 = p * p * (1.0 / 12.0);
            let a = 1.0 + 0.5 * p + p2;
            let b = 1.0 - p2;
            let v = (k_left + prev[t + 1]) * a - prev[t] * b;
            cur[t + 1] = v;
            k_left = v;
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[cols]
}
