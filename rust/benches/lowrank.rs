//! Low-rank vs exact signature-kernel Gram / MMD² scaling: the exact path
//! is quadratic in corpus size n (n² PDE solves for one Gram), the Nyström
//! and random-signature-feature paths are O(n·r²) at rank r. The suite
//! sweeps n at fixed r = 32 and records both, plus the rank sweep at fixed
//! n, into `bench_results/BENCH_lowrank.json`.

use pysiglib::bench::{bench_runs, Suite};
use pysiglib::kernel::{
    try_gram, try_gram_lowrank, try_mmd2, try_mmd2_lowrank, FeatureMap, KernelOptions,
    LowRankSpec,
};
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

fn main() {
    let runs = bench_runs(5);
    let (l, d, rank) = (32usize, 3usize, 32usize);
    let opts = KernelOptions::default();
    let mut suite = Suite::new("lowrank");
    for n in [64usize, 128, 256] {
        let tag = format!("n{n}");
        let mut rng = Rng::new(90);
        let x = rng.brownian_batch(n, l, d, 0.3);
        let y = rng.brownian_batch(n, l, d, 0.35);
        let xb = PathBatch::uniform(&x, n, l, d).unwrap();
        let yb = PathBatch::uniform(&y, n, l, d).unwrap();

        suite.time(&format!("{tag}/gram/exact"), runs, || {
            std::hint::black_box(try_gram(&xb, &yb, &opts).unwrap());
        });
        // Build + featurise + multiply every run: the honest end-to-end
        // cost of the approximation, not just the GEMM.
        suite.time(&format!("{tag}/gram/nystrom_r{rank}"), runs, || {
            let map = FeatureMap::try_build(&LowRankSpec::nystrom(rank, 7), &opts, &yb).unwrap();
            std::hint::black_box(try_gram_lowrank(&map, &xb, &yb).unwrap());
        });
        suite.time(&format!("{tag}/gram/randsig_r{rank}"), runs, || {
            let map =
                FeatureMap::try_build(&LowRankSpec::random_sig(rank, 4, 7), &opts, &yb).unwrap();
            std::hint::black_box(try_gram_lowrank(&map, &xb, &yb).unwrap());
        });

        suite.time(&format!("{tag}/mmd2/exact"), runs, || {
            std::hint::black_box(try_mmd2(&xb, &yb, &opts).unwrap());
        });
        suite.time(&format!("{tag}/mmd2/nystrom_r{rank}"), runs, || {
            let map = FeatureMap::try_build(&LowRankSpec::nystrom(rank, 7), &opts, &yb).unwrap();
            std::hint::black_box(try_mmd2_lowrank(&map, &xb, &yb).unwrap());
        });

        // Derived speedup rows for the JSON trajectory.
        if let (Some(exact), Some(lr)) = (
            suite.get(&format!("{tag}/gram/exact")),
            suite.get(&format!("{tag}/gram/nystrom_r{rank}")),
        ) {
            suite.record(&format!("{tag}/gram/speedup_nystrom_x"), exact / lr);
        }
    }

    // Rank sweep at the largest corpus: accuracy/cost knob.
    let n = 256usize;
    let mut rng = Rng::new(91);
    let x = rng.brownian_batch(n, l, d, 0.3);
    let y = rng.brownian_batch(n, l, d, 0.35);
    let xb = PathBatch::uniform(&x, n, l, d).unwrap();
    let yb = PathBatch::uniform(&y, n, l, d).unwrap();
    for r in [8usize, 32, 128] {
        suite.time(&format!("rank_sweep_n{n}/gram/nystrom_r{r}"), runs, || {
            let map = FeatureMap::try_build(&LowRankSpec::nystrom(r, 7), &opts, &yb).unwrap();
            std::hint::black_box(try_gram_lowrank(&map, &xb, &yb).unwrap());
        });
    }
}
