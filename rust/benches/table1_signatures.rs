//! Table 1: truncated-signature runtime, forward and backward, serial and
//! parallel, against reimplementations of the comparator libraries'
//! algorithms (esig → naive out-of-place products; iisignature → direct
//! Algorithm 1, with forward recomputation in the backward pass;
//! signatory/pySigLib → Horner Algorithm 2).
//!
//! Paper shapes: (B, L, d, N) ∈ {(128,256,4,6), (128,512,8,5),
//! (128,1024,16,4)}. Protocol: minimum over runs (paper: 50; default here 3,
//! override with PYSIGLIB_BENCH_RUNS).

use pysiglib::baselines::{iisig_backward, naive_signature};
use pysiglib::bench::{bench_runs, Suite};
use pysiglib::sig::{batch_signature, batch_signature_vjp, sig_length, SigMethod, SigOptions};
use pysiglib::util::pool::parallel_for;
use pysiglib::util::rng::Rng;

fn main() {
    let runs = bench_runs(3);
    let slow_runs = bench_runs(1);
    let mut suite = Suite::new("table1_signatures");
    let configs = [(128usize, 256usize, 4usize, 6usize), (128, 512, 8, 5), (128, 1024, 16, 4)];
    for (b, l, d, n) in configs {
        let tag = format!("B{b}_L{l}_d{d}_N{n}");
        let mut rng = Rng::new(1);
        let paths = rng.brownian_batch(b, l, d, 0.2);
        let slen = sig_length(d, n);

        // ---------------- forward, serial ----------------
        suite.time(&format!("{tag}/fwd/serial/esig-like(naive)"), slow_runs, || {
            for i in 0..b {
                std::hint::black_box(naive_signature(&paths[i * l * d..(i + 1) * l * d], l, d, n));
            }
        });
        suite.time(&format!("{tag}/fwd/serial/iisig-like(direct)"), runs, || {
            std::hint::black_box(batch_signature(
                &paths,
                b,
                l,
                d,
                &SigOptions::new(n).method(SigMethod::Direct).serial(),
            ));
        });
        suite.time(&format!("{tag}/fwd/serial/pysiglib(horner)"), runs, || {
            std::hint::black_box(batch_signature(&paths, b, l, d, &SigOptions::new(n).serial()));
        });

        // ---------------- forward, parallel ----------------
        suite.time(&format!("{tag}/fwd/parallel/signatory-like(direct)"), runs, || {
            std::hint::black_box(batch_signature(
                &paths,
                b,
                l,
                d,
                &SigOptions::new(n).method(SigMethod::Direct),
            ));
        });
        suite.time(&format!("{tag}/fwd/parallel/pysiglib(horner)"), runs, || {
            std::hint::black_box(batch_signature(&paths, b, l, d, &SigOptions::new(n)));
        });

        // ---------------- backward ----------------
        let mut gs = vec![0.0; b * slen];
        Rng::new(2).fill_normal(&mut gs);

        suite.time(&format!("{tag}/bwd/serial/iisig-like(recompute)"), slow_runs, || {
            for i in 0..b {
                std::hint::black_box(iisig_backward(
                    &paths[i * l * d..(i + 1) * l * d],
                    l,
                    d,
                    n,
                    &gs[i * slen..(i + 1) * slen],
                ));
            }
        });
        suite.time(&format!("{tag}/bwd/serial/pysiglib"), runs, || {
            std::hint::black_box(batch_signature_vjp(
                &paths,
                &gs,
                b,
                l,
                d,
                &SigOptions::new(n).serial(),
            ));
        });
        suite.time(&format!("{tag}/bwd/parallel/signatory-like(recompute)"), runs, || {
            // Parallel version of the recompute-based backward.
            parallel_for(b, |i| {
                std::hint::black_box(iisig_backward(
                    &paths[i * l * d..(i + 1) * l * d],
                    l,
                    d,
                    n,
                    &gs[i * slen..(i + 1) * slen],
                ));
            });
        });
        suite.time(&format!("{tag}/bwd/parallel/pysiglib"), runs, || {
            std::hint::black_box(batch_signature_vjp(&paths, &gs, b, l, d, &SigOptions::new(n)));
        });
    }

    // Paper-shape summary: who wins and by what factor.
    println!("\nspeedup summary (comparator / pysiglib):");
    for (b, l, d, n) in configs {
        let tag = format!("B{b}_L{l}_d{d}_N{n}");
        let naive = suite.get(&format!("{tag}/fwd/serial/esig-like(naive)"));
        let direct = suite.get(&format!("{tag}/fwd/serial/iisig-like(direct)"));
        let horner = suite.get(&format!("{tag}/fwd/serial/pysiglib(horner)"));
        if let (Some(a), Some(b_), Some(h)) = (naive, direct, horner) {
            println!(
                "  {tag}: fwd serial esig/pysiglib = {:.2}x, iisig/pysiglib = {:.2}x",
                a / h,
                b_ / h
            );
        }
    }
}
