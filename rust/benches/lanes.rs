//! CI-scale lane-engine suite — the bench-regression gate's lane
//! trajectory. Times the tiled Gram at scalar, W = 4 and W = 8 lane widths
//! across corpus sizes n ∈ {64, 128, 256}, uniform and ragged, and derives
//! the lane-over-scalar **median** speedups the gate floors (the `expect_min`
//! rows in `BENCH_lanes.json`: lane-batched Gram must beat the scalar
//! median at n = 256 on multi-pair tiles). Lane widths are pinned through
//! [`TileScheduler::with_lanes`] so the schedule under test does not depend
//! on the runner's environment.

use pysiglib::bench::{bench_runs, Suite};
use pysiglib::corpus::TileScheduler;
use pysiglib::kernel::KernelOptions;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

const WIDTHS: [(&str, usize); 3] = [("scalar", 0), ("w4", 4), ("w8", 8)];

fn main() {
    let runs = bench_runs(3);
    let d = 3usize;
    let l = 24usize;
    let opts = KernelOptions::default();
    let mut rng = Rng::new(61);
    let mut suite = Suite::new("lanes");

    for &n in &[64usize, 128, 256] {
        // Uniform corpus: every tile row is one long equal-length run, so
        // W = 8 groups fill completely.
        let data = rng.brownian_batch(n, l, d, 0.25);
        let xb = PathBatch::uniform(&data, n, l, d).unwrap();
        let mut out = vec![0.0; n * n];
        for (label, width) in WIDTHS {
            suite.time(&format!("n{n}/uniform/gram/{label}"), runs, || {
                TileScheduler::with_tile(16)
                    .with_lanes(width)
                    .gram_into(&xb, &xb, &opts, &mut out)
                    .unwrap();
                std::hint::black_box(&out);
            });
        }
        for (label, width) in [("w4", 4usize), ("w8", 8)] {
            if let (Some(s), Some(w)) = (
                suite.get_median(&format!("n{n}/uniform/gram/scalar")),
                suite.get_median(&format!("n{n}/uniform/gram/{label}")),
            ) {
                suite.record(
                    &format!("n{n}/uniform/gram/speedup_{label}_x"),
                    s / w.max(1e-12),
                );
            }
        }

        // Ragged corpus with repeated lengths (l/2, 3l/4, l cycling): the
        // dispatcher's grouping-by-shape-class is what keeps lanes full.
        let lens: Vec<usize> = (0..n).map(|i| [l / 2, 3 * l / 4, l][i % 3]).collect();
        let mut rdata = Vec::new();
        for &pl in &lens {
            rdata.extend(rng.brownian_path(pl, d, 0.25));
        }
        let rb = PathBatch::ragged(&rdata, &lens, d).unwrap();
        for (label, width) in WIDTHS {
            suite.time(&format!("n{n}/ragged/gram/{label}"), runs, || {
                TileScheduler::with_tile(16)
                    .with_lanes(width)
                    .gram_into(&rb, &rb, &opts, &mut out)
                    .unwrap();
                std::hint::black_box(&out);
            });
        }
        if let (Some(s), Some(w)) = (
            suite.get_median(&format!("n{n}/ragged/gram/scalar")),
            suite.get_median(&format!("n{n}/ragged/gram/w4")),
        ) {
            suite.record(&format!("n{n}/ragged/gram/speedup_w4_x"), s / w.max(1e-12));
        }
    }
}
