//! Figure 2: signature-kernel forward/backward runtime as a function of
//! stream length, for a batch of 32 paths of dimension 5 (the paper's
//! figure workload). Series: sigkernel-like full grid vs pysiglib row sweep
//! (forward), approximate-PDE vs exact Algorithm-4 (backward); the blocked
//! GPU-scheme sweep rides along to show its scaling.

use pysiglib::baselines::full_grid_kernel;
use pysiglib::bench::{bench_runs, Suite};
use pysiglib::kernel::{
    batch_kernel, batch_kernel_vjp, delta_matrix, sig_kernel_vjp_pde_approx, KernelOptions,
    SolverKind,
};
use pysiglib::transforms::Transform;
use pysiglib::util::pool::parallel_for;
use pysiglib::util::rng::Rng;

fn main() {
    let runs = bench_runs(3);
    let (b, d) = (32usize, 5usize);
    let mut suite = Suite::new("figure2_kernel_scaling");
    for l in [64usize, 128, 256, 512, 1024, 2048] {
        let tag = format!("L{l}");
        let mut rng = Rng::new(31);
        let scale = 1.0 / (l as f64).sqrt();
        let xs = rng.brownian_batch(b, l, d, scale);
        let ys = rng.brownian_batch(b, l, d, scale);
        let gk = vec![1.0; b];

        suite.time(&format!("{tag}/fwd/sigkernel-like(fullgrid)"), runs, || {
            parallel_for(b, |i| {
                let (m, n, delta) = delta_matrix(
                    &xs[i * l * d..(i + 1) * l * d],
                    &ys[i * l * d..(i + 1) * l * d],
                    l,
                    l,
                    d,
                    Transform::None,
                );
                std::hint::black_box(full_grid_kernel(&delta, m, n, 0, 0).unwrap());
            });
        });
        suite.time(&format!("{tag}/fwd/pysiglib(row)"), runs, || {
            std::hint::black_box(batch_kernel(&xs, &ys, b, l, l, d, &KernelOptions::default()));
        });
        suite.time(&format!("{tag}/fwd/pysiglib(blocked)"), runs, || {
            std::hint::black_box(batch_kernel(
                &xs,
                &ys,
                b,
                l,
                l,
                d,
                &KernelOptions::default().solver(SolverKind::Blocked),
            ));
        });
        suite.time(&format!("{tag}/bwd/sigkernel-like(pde-approx)"), runs, || {
            parallel_for(b, |i| {
                std::hint::black_box(sig_kernel_vjp_pde_approx(
                    &xs[i * l * d..(i + 1) * l * d],
                    &ys[i * l * d..(i + 1) * l * d],
                    l,
                    l,
                    d,
                    &KernelOptions::default(),
                    1.0,
                ));
            });
        });
        suite.time(&format!("{tag}/bwd/pysiglib(exact)"), runs, || {
            std::hint::black_box(batch_kernel_vjp(
                &xs,
                &ys,
                &gk,
                b,
                l,
                l,
                d,
                &KernelOptions::default(),
            ));
        });
    }
}
