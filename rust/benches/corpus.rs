//! Corpus-serving latency: cold registration+query vs warm re-query vs
//! incremental append+query, across corpus sizes n — the serving story of
//! the corpus registry. A cold MMD² query pays the full O(n²) corpus
//! self-Gram; a warm query reuses it and pays only O(q² + q·n); an append
//! of k paths pays only the new O(k·n) strips. The derived
//! `speedup_warm_x` rows record the headline ratio (warm re-query is
//! expected ≥5× faster than cold at n = 256) into
//! `bench_results/BENCH_corpus.json`.

// The warm-state helper threads the full workload description; splitting it
// into a struct would only obscure a benchmark.
#![allow(clippy::too_many_arguments)]

use pysiglib::bench::{bench_runs, Suite};
use pysiglib::corpus::CorpusRegistry;
use pysiglib::kernel::{KernelOptions, LowRankSpec};
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

/// A registry with the corpus registered and its exact (and, when `spec` is
/// set, low-rank) caches warmed by one query.
fn warmed(
    corpus: &[f64],
    n: usize,
    l: usize,
    d: usize,
    query: &[f64],
    q: usize,
    opts: &KernelOptions,
    spec: Option<&LowRankSpec>,
) -> (CorpusRegistry, pysiglib::corpus::CorpusId) {
    let reg = CorpusRegistry::new();
    let cb = PathBatch::uniform(corpus, n, l, d).unwrap();
    let qb = PathBatch::uniform(query, q, l, d).unwrap();
    let id = reg.register(&cb).unwrap();
    reg.mmd2_query(id, &qb, opts, spec).unwrap();
    (reg, id)
}

fn main() {
    let runs = bench_runs(3);
    let (l, d, q, k, rank) = (16usize, 3usize, 16usize, 16usize, 32usize);
    let opts = KernelOptions::default();
    let mut suite = Suite::new("corpus");
    for n in [64usize, 128, 256] {
        let tag = format!("n{n}");
        let mut rng = Rng::new(95);
        let corpus = rng.brownian_batch(n, l, d, 0.3);
        let query = rng.brownian_batch(q, l, d, 0.35);
        let extra = rng.brownian_batch(k, l, d, 0.3);
        let qb = PathBatch::uniform(&query, q, l, d).unwrap();

        // Cold: register + first query (builds the n×n self-Gram).
        suite.time(&format!("{tag}/mmd2/cold"), runs, || {
            let reg = CorpusRegistry::new();
            let cb = PathBatch::uniform(&corpus, n, l, d).unwrap();
            let id = reg.register(&cb).unwrap();
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, None).unwrap());
        });

        // Warm: the corpus state is cached; only K_qq and K_qc are solved.
        let (reg, id) = warmed(&corpus, n, l, d, &query, q, &opts, None);
        suite.time(&format!("{tag}/mmd2/warm"), runs, || {
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, None).unwrap());
        });

        // Append k paths + query: only the old×new strips are solved. Each
        // timed run consumes its own pre-warmed registry (appending twice
        // to one registry would grow the corpus across runs).
        let mut pool: Vec<_> = (0..runs + 1)
            .map(|_| warmed(&corpus, n, l, d, &query, q, &opts, None))
            .collect();
        suite.time(&format!("{tag}/mmd2/append{k}"), runs, || {
            let (reg, id) = pool.pop().expect("one registry per run");
            let eb = PathBatch::uniform(&extra, k, l, d).unwrap();
            reg.append(id, &eb).unwrap();
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, None).unwrap());
        });

        // Low-rank (Nyström rank 32): cold builds the feature map + Φ_c,
        // warm featurises only the q query rows.
        let spec = LowRankSpec::nystrom(rank, 7);
        suite.time(&format!("{tag}/mmd2_lowrank_r{rank}/cold"), runs, || {
            let reg = CorpusRegistry::new();
            let cb = PathBatch::uniform(&corpus, n, l, d).unwrap();
            let id = reg.register(&cb).unwrap();
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, Some(&spec)).unwrap());
        });
        let (lreg, lid) = warmed(&corpus, n, l, d, &query, q, &opts, Some(&spec));
        suite.time(&format!("{tag}/mmd2_lowrank_r{rank}/warm"), runs, || {
            std::hint::black_box(lreg.mmd2_query(lid, &qb, &opts, Some(&spec)).unwrap());
        });

        // Derived ratio rows for the JSON trajectory (runs = 0, so the CI
        // regression gate skips them as non-timing rows).
        let lr_family = format!("mmd2_lowrank_r{rank}");
        for family in ["mmd2", lr_family.as_str()] {
            if let (Some(cold), Some(warm)) = (
                suite.get(&format!("{tag}/{family}/cold")),
                suite.get(&format!("{tag}/{family}/warm")),
            ) {
                suite.record(&format!("{tag}/{family}/speedup_warm_x"), cold / warm);
            }
        }
    }
}
