//! CI-scale signature suite — the bench-regression gate's signature
//! trajectory. Deliberately small, fixed workloads (seconds, not minutes)
//! with stable case names: the committed repo-root `BENCH_sig.json`
//! baseline is compared against this suite's medians on every CI run, so
//! renaming a case here requires refreshing the baseline. The paper-scale
//! sweeps live in `figure1_sig_scaling` / `table1_signatures`.

use pysiglib::bench::{bench_runs, Suite};
use pysiglib::sig::{batch_signature, batch_signature_vjp, sig_length, SigMethod, SigOptions};
use pysiglib::util::rng::Rng;

fn main() {
    let runs = bench_runs(5);
    let (b, l, d) = (32usize, 128usize, 4usize);
    let mut rng = Rng::new(21);
    let paths = rng.brownian_batch(b, l, d, 0.2);
    let mut suite = Suite::new("sig");

    for depth in [3usize, 5] {
        let tag = format!("b{b}_l{l}_d{d}_n{depth}");
        suite.time(&format!("{tag}/fwd/horner"), runs, || {
            std::hint::black_box(batch_signature(&paths, b, l, d, &SigOptions::new(depth)));
        });
        suite.time(&format!("{tag}/fwd/direct"), runs, || {
            std::hint::black_box(batch_signature(
                &paths,
                b,
                l,
                d,
                &SigOptions::new(depth).method(SigMethod::Direct),
            ));
        });
        let slen = sig_length(d, depth);
        let mut gs = vec![0.0; b * slen];
        Rng::new(22).fill_normal(&mut gs);
        suite.time(&format!("{tag}/bwd/deconstruction"), runs, || {
            std::hint::black_box(batch_signature_vjp(
                &paths,
                &gs,
                b,
                l,
                d,
                &SigOptions::new(depth),
            ));
        });
    }
}
