//! Figure 1: signature forward/backward runtime as a function of the
//! truncation level N, for a batch of 32 paths of length 1024, dimension 5
//! (the paper's exact figure workload). Series: esig-like naive, direct
//! (Algorithm 1), Horner (Algorithm 2) forward; recompute-based vs
//! deconstruction-based backward.

use pysiglib::baselines::{iisig_backward, naive_signature};
use pysiglib::bench::{bench_runs, Suite};
use pysiglib::sig::{batch_signature, batch_signature_vjp, sig_length, SigMethod, SigOptions};
use pysiglib::util::pool::parallel_for;
use pysiglib::util::rng::Rng;

fn main() {
    let runs = bench_runs(3);
    let (b, l, d) = (32usize, 1024usize, 5usize);
    let mut rng = Rng::new(11);
    let paths = rng.brownian_batch(b, l, d, 0.2);
    let mut suite = Suite::new("figure1_sig_scaling");

    for n in 1..=6 {
        let slen = sig_length(d, n);
        let mut gs = vec![0.0; b * slen];
        Rng::new(12).fill_normal(&mut gs);

        if n <= 5 {
            // esig-like naive blows up fast; cap its depth like the figure's
            // cut-off axis.
            suite.time(&format!("N{n}/fwd/esig-like(naive)"), 1, || {
                parallel_for(b, |i| {
                    std::hint::black_box(naive_signature(
                        &paths[i * l * d..(i + 1) * l * d],
                        l,
                        d,
                        n,
                    ));
                });
            });
        } else {
            suite.record(&format!("N{n}/fwd/esig-like(naive)"), f64::NAN);
        }
        suite.time(&format!("N{n}/fwd/direct"), runs, || {
            std::hint::black_box(batch_signature(
                &paths,
                b,
                l,
                d,
                &SigOptions::new(n).method(SigMethod::Direct),
            ));
        });
        suite.time(&format!("N{n}/fwd/pysiglib(horner)"), runs, || {
            std::hint::black_box(batch_signature(&paths, b, l, d, &SigOptions::new(n)));
        });
        suite.time(&format!("N{n}/bwd/recompute-based"), runs, || {
            parallel_for(b, |i| {
                std::hint::black_box(iisig_backward(
                    &paths[i * l * d..(i + 1) * l * d],
                    l,
                    d,
                    n,
                    &gs[i * slen..(i + 1) * slen],
                ));
            });
        });
        suite.time(&format!("N{n}/bwd/pysiglib"), runs, || {
            std::hint::black_box(batch_signature_vjp(
                &paths,
                &gs,
                b,
                l,
                d,
                &SigOptions::new(n),
            ));
        });
    }
}
