//! CI-scale signature-kernel suite — the bench-regression gate's kernel
//! trajectory. Small fixed workloads with stable case names, compared on
//! every CI run against the committed repo-root `BENCH_kernel.json`
//! baseline (renaming a case requires refreshing the baseline). The
//! paper-scale sweeps live in `figure2_kernel_scaling` / `table2_kernels`.

use pysiglib::bench::{bench_runs, Suite};
use pysiglib::kernel::{batch_kernel, batch_kernel_vjp, try_gram, KernelOptions, SolverKind};
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

fn main() {
    let runs = bench_runs(5);
    let (b, l, d) = (16usize, 96usize, 3usize);
    let mut rng = Rng::new(31);
    let scale = 1.0 / (l as f64).sqrt();
    let xs = rng.brownian_batch(b, l, d, scale);
    let ys = rng.brownian_batch(b, l, d, scale);
    let gk = vec![1.0; b];
    let mut suite = Suite::new("kernel");

    let tag = format!("b{b}_l{l}_d{d}");
    suite.time(&format!("{tag}/fwd/row"), runs, || {
        std::hint::black_box(batch_kernel(&xs, &ys, b, l, l, d, &KernelOptions::default()));
    });
    suite.time(&format!("{tag}/fwd/blocked"), runs, || {
        std::hint::black_box(batch_kernel(
            &xs,
            &ys,
            b,
            l,
            l,
            d,
            &KernelOptions::default().solver(SolverKind::Blocked),
        ));
    });
    suite.time(&format!("{tag}/fwd/dyadic11"), runs, || {
        std::hint::black_box(batch_kernel(
            &xs,
            &ys,
            b,
            l,
            l,
            d,
            &KernelOptions::default().dyadic(1, 1),
        ));
    });
    suite.time(&format!("{tag}/bwd/exact"), runs, || {
        std::hint::black_box(batch_kernel_vjp(
            &xs,
            &ys,
            &gk,
            b,
            l,
            l,
            d,
            &KernelOptions::default(),
        ));
    });

    // A small Gram: the n² workload class the corpus registry amortises.
    let (gn, gl) = (48usize, 24usize);
    let gx = rng.brownian_batch(gn, gl, d, 0.3);
    let gy = rng.brownian_batch(gn, gl, d, 0.35);
    let gxb = PathBatch::uniform(&gx, gn, gl, d).unwrap();
    let gyb = PathBatch::uniform(&gy, gn, gl, d).unwrap();
    suite.time(&format!("gram_n{gn}_l{gl}_d{d}"), runs, || {
        std::hint::black_box(try_gram(&gxb, &gyb, &KernelOptions::default()).unwrap());
    });
}
