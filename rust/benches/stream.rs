//! Streaming-extension latency: appending L_new points to a registered
//! length-L path via Goursat border strips vs re-registering the grown
//! corpus from scratch, across L — the tentpole claim of the streaming
//! subsystem. A re-register pays every O(L²) pair solve again; a
//! steady-state extend pays only the O(L_new·L) strips of the pairs that
//! touch the extended path (the first extend additionally pays a one-off
//! O(L²) border-retaining solve, recorded as `warmup`). The derived
//! `speedup_vs_rescratch_x` rows record the headline ratio (≥20× at
//! L = 2048) into `bench_results/BENCH_stream.json`, alongside
//! sliding-window churn throughput (push-evict cycles at capacity and the
//! exponentially-weighted window MMD² score).

use pysiglib::bench::{bench_runs, Suite};
use pysiglib::corpus::{CorpusRegistry, SlidingCorpus};
use pysiglib::kernel::KernelOptions;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;
use std::sync::Arc;

/// A registry with the `n×l` corpus registered and its exact self-Gram
/// built by one (tiny) query — the state a serving process holds when the
/// first streamed points arrive.
fn warmed(
    corpus: &[f64],
    n: usize,
    l: usize,
    d: usize,
    query: &[f64],
    ql: usize,
    opts: &KernelOptions,
) -> (CorpusRegistry, pysiglib::corpus::CorpusId) {
    let reg = CorpusRegistry::new();
    let cb = PathBatch::uniform(corpus, n, l, d).unwrap();
    let qb = PathBatch::uniform(query, 1, ql, d).unwrap();
    let id = reg.register(&cb).unwrap();
    reg.mmd2_query(id, &qb, opts, None).unwrap();
    (reg, id)
}

fn main() {
    let runs = bench_runs(3);
    let (n, d, add, ql) = (4usize, 2usize, 16usize, 8usize);
    let opts = KernelOptions::default();
    let mut suite = Suite::new("stream");

    for l in [128usize, 512, 2048] {
        let tag = format!("l{l}");
        let mut rng = Rng::new(113);
        let corpus = rng.brownian_batch(n, l, d, 0.3);
        let ext = rng.brownian_batch(1, add, d, 0.3);
        let query = rng.brownian_batch(1, ql, d, 0.35);
        let qb = PathBatch::uniform(&query, 1, ql, d).unwrap();

        // Rescratch: the grown corpus (path 0 carries the extra points)
        // registered from nothing, self-Gram rebuilt by the query — the
        // cost streaming avoids. Built ragged so the shape matches what an
        // extend produces.
        let mut grown = corpus.clone();
        grown.splice(l * d..l * d, ext.iter().copied());
        let mut glens = vec![l; n];
        glens[0] = l + add;
        suite.time(&format!("{tag}/extend/rescratch"), runs, || {
            let reg = CorpusRegistry::new();
            let gb = PathBatch::ragged(&grown, &glens, d).unwrap();
            let id = reg.register(&gb).unwrap();
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, None).unwrap());
        });

        // Warm-up extend: the first extension of a queried corpus retains
        // every border on the way (O(L²) once per pair). Each run consumes
        // its own registry — a second extend would measure the steady state.
        let mut pool: Vec<_> = (0..runs + 1)
            .map(|_| warmed(&corpus, n, l, d, &query, ql, &opts))
            .collect();
        suite.time(&format!("{tag}/extend/warmup"), runs, || {
            let (reg, id) = pool.pop().expect("one registry per run");
            reg.extend_path(id, 0, &ext).unwrap();
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, None).unwrap());
        });

        // Steady-state extend: borders already retained (by a throwaway
        // 1-point extend), so the timed extend solves only the
        // O(L_new·L) strips of the pairs touching path 0, then re-queries.
        let mut pool: Vec<_> = (0..runs + 1)
            .map(|_| {
                let (reg, id) = warmed(&corpus, n, l, d, &query, ql, &opts);
                reg.extend_path(id, 0, &ext[..d]).unwrap();
                (reg, id)
            })
            .collect();
        suite.time(&format!("{tag}/extend/steady"), runs, || {
            let (reg, id) = pool.pop().expect("one registry per run");
            reg.extend_path(id, 0, &ext[d..]).unwrap();
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, None).unwrap());
        });

        // Derived ratio row (runs = 0, so the CI regression gate treats it
        // as a non-timing row; the expect_min floor still applies).
        if let (Some(scratch), Some(steady)) = (
            suite.get(&format!("{tag}/extend/rescratch")),
            suite.get(&format!("{tag}/extend/steady")),
        ) {
            suite.record(&format!("{tag}/extend/speedup_vs_rescratch_x"), scratch / steady);
        }
    }

    // Window churn: a capacity-8 sliding window of length-256 paths at
    // steady state. Each push appends one path's Gram strips and evicts the
    // oldest (suffix shrink) — corpus shape is invariant, so one window
    // serves every run.
    let (w, lw) = (8usize, 256usize);
    let mut rng = Rng::new(131);
    let seed = rng.brownian_batch(w, lw, d, 0.3);
    let fresh = rng.brownian_batch((runs + 1) * 8, lw, d, 0.3);
    let sb = PathBatch::uniform(&seed, w, lw, d).unwrap();
    let registry = Arc::new(CorpusRegistry::new());
    let mut sc = SlidingCorpus::try_new(registry.clone(), &sb, w, None).unwrap();
    let wq = rng.brownian_batch(1, ql, d, 0.35);
    let wqb = PathBatch::uniform(&wq, 1, ql, d).unwrap();
    registry.mmd2_query(sc.id(), &wqb, &opts, None).unwrap();
    let mut next = 0usize;
    suite.time("churn/push8", runs, || {
        for _ in 0..8 {
            let at = (next % ((runs + 1) * 8)) * lw * d;
            sc.push(&fresh[at..at + lw * d], lw).unwrap();
            next += 1;
        }
        std::hint::black_box(sc.len());
    });

    // Weighted window score: MMD²(8-path query window, 8-path reference)
    // with decay 0.9 served from the warm reference self-Gram.
    let refc = rng.brownian_batch(w, lw, d, 0.3);
    let window = rng.brownian_batch(w, lw, d, 0.35);
    let rb = PathBatch::uniform(&refc, w, lw, d).unwrap();
    let wb = PathBatch::uniform(&window, w, lw, d).unwrap();
    let reg = CorpusRegistry::new();
    let rid = reg.register(&rb).unwrap();
    reg.mmd2_window(rid, &wb, &opts, 0.9).unwrap();
    suite.time("churn/mmd2_window", runs, || {
        std::hint::black_box(reg.mmd2_window(rid, &wb, &opts, 0.9).unwrap());
    });
}
