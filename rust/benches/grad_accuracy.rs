//! §3.4 gradient-accuracy experiment: the paper's motivation for Algorithm 4
//! is that the second-PDE gradients are *inaccurate when the path length or
//! dyadic order is low*. This bench quantifies that: relative L2 error of
//! the approximate scheme against (a) the exact Algorithm-4 gradients and
//! (b) central finite differences (ground truth), plus the runtime of each,
//! across lengths and dyadic orders.

use pysiglib::bench::Suite;
use pysiglib::kernel::{
    sig_kernel, sig_kernel_vjp, sig_kernel_vjp_pde_approx, KernelOptions,
};
use pysiglib::util::linalg::rel_err;
use pysiglib::util::rng::Rng;

fn finite_diff_grad(x: &[f64], y: &[f64], l: usize, d: usize, opts: &KernelOptions) -> Vec<f64> {
    let eps = 1e-6;
    let mut g = vec![0.0; l * d];
    for i in 0..l * d {
        let mut xp = x.to_vec();
        xp[i] += eps;
        let mut xm = x.to_vec();
        xm[i] -= eps;
        g[i] = (sig_kernel(&xp, y, l, l, d, opts) - sig_kernel(&xm, y, l, l, d, opts))
            / (2.0 * eps);
    }
    g
}

fn main() {
    let mut suite = Suite::new("grad_accuracy");
    println!(
        "\n{:<10} {:>7} | {:>14} {:>14} | {:>12} {:>12}",
        "length", "dyadic", "approx-vs-fd", "exact-vs-fd", "t_exact(s)", "t_approx(s)"
    );
    let d = 3;
    let mut rng = Rng::new(71);
    for l in [3usize, 5, 9, 17, 33] {
        for lam in [0u32, 1, 2] {
            let x = rng.brownian_path(l, d, 0.4);
            let y = rng.brownian_path(l, d, 0.4);
            let opts = KernelOptions::default().dyadic(lam, lam);
            let fd = finite_diff_grad(&x, &y, l, d, &opts);
            let (exact, _) = sig_kernel_vjp(&x, &y, l, l, d, &opts, 1.0);
            let (approx, _) = sig_kernel_vjp_pde_approx(&x, &y, l, l, d, &opts, 1.0);
            let err_approx = rel_err(&approx, &fd);
            let err_exact = rel_err(&exact, &fd);
            let t_exact = pysiglib::util::timing::min_time_over(5, || {
                std::hint::black_box(sig_kernel_vjp(&x, &y, l, l, d, &opts, 1.0));
            });
            let t_approx = pysiglib::util::timing::min_time_over(5, || {
                std::hint::black_box(sig_kernel_vjp_pde_approx(&x, &y, l, l, d, &opts, 1.0));
            });
            println!(
                "{:<10} {:>7} | {:>14.3e} {:>14.3e} | {:>12.6} {:>12.6}",
                l, lam, err_approx, err_exact, t_exact, t_approx
            );
            suite.record(&format!("L{l}_lam{lam}/err_approx_vs_fd"), err_approx);
            suite.record(&format!("L{l}_lam{lam}/err_exact_vs_fd"), err_exact);
            suite.record(&format!("L{l}_lam{lam}/t_exact"), t_exact);
            suite.record(&format!("L{l}_lam{lam}/t_approx"), t_approx);
        }
    }
    println!(
        "\nreading: exact-vs-fd should sit at finite-difference noise (~1e-7)\n\
         for every configuration, while approx-vs-fd is orders of magnitude\n\
         worse at short lengths / low dyadic orders and converges as either grows\n\
         — the paper's §3.4 claim."
    );
}
