//! Restart latency: restoring a corpus registry from a snapshot vs
//! rebuilding it cold. A cold start pays path registration plus the full
//! O(n²) corpus self-Gram on the first MMD² query; a restore reads the
//! snapshot's serialized exact cache (and low-rank features when present)
//! and answers the same query warm. The derived `restore_vs_cold_x` row
//! records the headline ratio (restore is expected ≥5× faster than cold at
//! n = 256) into `bench_results/BENCH_recovery.json`.

use pysiglib::bench::{bench_runs, Suite};
use pysiglib::corpus::CorpusRegistry;
use pysiglib::kernel::KernelOptions;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

fn main() {
    let runs = bench_runs(3);
    let (l, d, q) = (16usize, 3usize, 16usize);
    let opts = KernelOptions::default();
    let dir = std::env::temp_dir().join(format!("pysiglib-bench-recovery-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench snapshot dir");
    let mut suite = Suite::new("recovery");
    for n in [64usize, 256] {
        let tag = format!("n{n}");
        let mut rng = Rng::new(95);
        let corpus = rng.brownian_batch(n, l, d, 0.3);
        let query = rng.brownian_batch(q, l, d, 0.35);
        let qb = PathBatch::uniform(&query, q, l, d).unwrap();

        // Cold: register + first query (builds the n×n self-Gram).
        suite.time(&format!("{tag}/cold"), runs, || {
            let reg = CorpusRegistry::new();
            let cb = PathBatch::uniform(&corpus, n, l, d).unwrap();
            let id = reg.register(&cb).unwrap();
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, None).unwrap());
        });

        // Snapshot a warmed registry once; restoring is read-only, so one
        // file serves every timed run.
        let file = dir.join(format!("{tag}.snapshot"));
        {
            let reg = CorpusRegistry::new();
            let cb = PathBatch::uniform(&corpus, n, l, d).unwrap();
            let id = reg.register(&cb).unwrap();
            reg.mmd2_query(id, &qb, &opts, None).unwrap();
            reg.snapshot_to(&file).unwrap();
        }

        // Restore: deserialize the corpus + its exact cache, answer warm.
        suite.time(&format!("{tag}/restore"), runs, || {
            let reg = CorpusRegistry::restore_from(&file).unwrap();
            let id = reg.ids().pop().expect("snapshot holds one corpus");
            std::hint::black_box(reg.mmd2_query(id, &qb, &opts, None).unwrap());
        });

        if let (Some(cold), Some(restore)) =
            (suite.get(&format!("{tag}/cold")), suite.get(&format!("{tag}/restore")))
        {
            suite.record(&format!("{tag}/restore_vs_cold_x"), cold / restore);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
