//! CI-scale backward-pass suite — the bench-regression gate's gradient
//! trajectory. Times full fwd+bwd MMD² training steps and the backward
//! alone at scalar, W = 4 and W = 8 lane widths across batch sizes
//! n ∈ {32, 64, 128}, uniform plus a ragged step at the largest size, and
//! derives the lane-over-scalar **median** backward speedups the gate
//! floors (the `expect_min` rows in `BENCH_grad.json`: the lane-batched
//! backward must not lose to the scalar schedule at n = 128). Widths are
//! pinned through [`Plan::with_lane_width`] so the schedule under test does
//! not depend on the runner's environment; the backward runs at whatever
//! width its record's plan was compiled with.

use pysiglib::bench::{bench_runs, Suite};
use pysiglib::engine::{OpSpec, Plan, ShapeClass};
use pysiglib::kernel::KernelOptions;
use pysiglib::util::rng::Rng;
use pysiglib::PathBatch;

const WIDTHS: [(&str, usize); 3] = [("scalar", 0), ("w4", 4), ("w8", 8)];

fn main() {
    let runs = bench_runs(3);
    let d = 3usize;
    let l = 20usize;
    let opts = KernelOptions::default();
    let mut rng = Rng::new(71);
    let mut suite = Suite::new("grad");

    for &n in &[32usize, 64, 128] {
        let x = rng.brownian_batch(n, l, d, 0.25);
        let y = rng.brownian_batch(n, l, d, 0.25);
        let xb = PathBatch::uniform(&x, n, l, d).unwrap();
        let yb = PathBatch::uniform(&y, n, l, d).unwrap();
        let shape = ShapeClass::for_pair(&xb, &yb);
        // Forward-only reference (scalar schedule): what a no-gradient
        // evaluation costs, for the bwd_over_fwd cost-model row.
        let fwd_plan = Plan::compile_forward(OpSpec::Mmd2(opts), shape)
            .unwrap()
            .with_lane_width(0);
        suite.time(&format!("n{n}/uniform/mmd2/fwd"), runs, || {
            std::hint::black_box(fwd_plan.execute_pair(&xb, &yb).unwrap().value());
        });
        for (label, width) in WIDTHS {
            let plan = Plan::compile(OpSpec::Mmd2(opts), shape)
                .unwrap()
                .with_lane_width(width);
            // One full training step: retained forward + exact backward.
            suite.time(&format!("n{n}/uniform/mmd2/fwdbwd/{label}"), runs, || {
                let rec = plan.execute_pair(&xb, &yb).unwrap();
                std::hint::black_box(rec.vjp(&[1.0]).unwrap());
            });
            // Backward alone, against a record produced once.
            let rec = plan.execute_pair(&xb, &yb).unwrap();
            suite.time(&format!("n{n}/uniform/mmd2/bwd/{label}"), runs, || {
                std::hint::black_box(rec.vjp(&[1.0]).unwrap());
            });
        }
        for label in ["w4", "w8"] {
            if let (Some(s), Some(w)) = (
                suite.get_median(&format!("n{n}/uniform/mmd2/bwd/scalar")),
                suite.get_median(&format!("n{n}/uniform/mmd2/bwd/{label}")),
            ) {
                suite.record(
                    &format!("n{n}/uniform/mmd2/bwd_speedup_{label}_x"),
                    s / w.max(1e-12),
                );
            }
        }
        if let (Some(f), Some(b)) = (
            suite.get_median(&format!("n{n}/uniform/mmd2/fwd")),
            suite.get_median(&format!("n{n}/uniform/mmd2/bwd/scalar")),
        ) {
            suite.record(&format!("n{n}/uniform/mmd2/bwd_over_fwd_x"), b / f.max(1e-12));
        }
    }

    // Ragged training step at the largest size: the backward dispatcher's
    // grouping-by-shape-class (with the width-independent length sort) is
    // what keeps lanes full here.
    let n = 128usize;
    let lens: Vec<usize> = (0..n).map(|i| [l / 2, 3 * l / 4, l][i % 3]).collect();
    let mut xdata = Vec::new();
    let mut ydata = Vec::new();
    for &pl in &lens {
        xdata.extend(rng.brownian_path(pl, d, 0.25));
        ydata.extend(rng.brownian_path(pl, d, 0.25));
    }
    let xb = PathBatch::ragged(&xdata, &lens, d).unwrap();
    let yb = PathBatch::ragged(&ydata, &lens, d).unwrap();
    let shape = ShapeClass::for_pair(&xb, &yb);
    for (label, width) in WIDTHS {
        let plan = Plan::compile(OpSpec::Mmd2(opts), shape)
            .unwrap()
            .with_lane_width(width);
        let rec = plan.execute_pair(&xb, &yb).unwrap();
        suite.time(&format!("n{n}/ragged/mmd2/bwd/{label}"), runs, || {
            std::hint::black_box(rec.vjp(&[1.0]).unwrap());
        });
    }
    if let (Some(s), Some(w)) = (
        suite.get_median(&format!("n{n}/ragged/mmd2/bwd/scalar")),
        suite.get_median(&format!("n{n}/ragged/mmd2/bwd/w4")),
    ) {
        suite.record(&format!("n{n}/ragged/mmd2/bwd_speedup_w4_x"), s / w.max(1e-12));
    }
}
