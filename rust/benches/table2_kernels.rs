//! Table 2: signature-kernel runtime, forward and backward, "CPU" and
//! "GPU"-scheme, against the sigkernel package's algorithmic choices.
//!
//! Paper shapes: (B, L, d) ∈ {(128,256,8), (128,512,16), (128,1024,32)},
//! dyadic order 0.
//!
//! Mapping (no GPU in this container — see DESIGN.md §Substitutions):
//!   CPU / sigkernel-like : full-grid solver with materialised refinement
//!   CPU / pysiglib       : two-row sweep, on-the-fly refinement
//!   GPU / sigkernel-like : one-thread-per-diagonal-entry scheme — *refuses*
//!                          L ≥ 1024 (the paper's dash), else the same sweep
//!   GPU / pysiglib       : blocked anti-diagonal scheme (32-row blocks,
//!                          3 rotating diagonals — the CUDA dataflow)
//!   bwd / sigkernel-like : approximate second-PDE gradients
//!   bwd / pysiglib       : exact Algorithm-4 gradients

use pysiglib::baselines::{full_grid_kernel, gpu_style_kernel};
use pysiglib::bench::{bench_runs, Suite};
use pysiglib::kernel::{
    batch_kernel, batch_kernel_vjp, delta_matrix, sig_kernel_vjp_pde_approx, KernelOptions,
    SolverKind,
};
use pysiglib::transforms::Transform;
use pysiglib::util::pool::parallel_for;
use pysiglib::util::rng::Rng;

fn main() {
    let runs = bench_runs(3);
    let mut suite = Suite::new("table2_kernels");
    let configs = [(128usize, 256usize, 8usize), (128, 512, 16), (128, 1024, 32)];
    for (b, l, d) in configs {
        let tag = format!("B{b}_L{l}_d{d}");
        let mut rng = Rng::new(21);
        let scale = 1.0 / (l as f64).sqrt(); // keep kernel values sane
        let xs = rng.brownian_batch(b, l, d, scale);
        let ys = rng.brownian_batch(b, l, d, scale);

        // Precompute per-pair deltas once for the baselines that take Δ
        // directly (they'd pay the same GEMM; excluding it isolates the
        // solver comparison — the GEMM is identical for both sides).
        // ---------------- forward, CPU ----------------
        suite.time(&format!("{tag}/fwd/cpu/sigkernel-like(fullgrid)"), runs, || {
            parallel_for(b, |i| {
                let (m, n, delta) = delta_matrix(
                    &xs[i * l * d..(i + 1) * l * d],
                    &ys[i * l * d..(i + 1) * l * d],
                    l,
                    l,
                    d,
                    Transform::None,
                );
                std::hint::black_box(full_grid_kernel(&delta, m, n, 0, 0).unwrap());
            });
        });
        suite.time(&format!("{tag}/fwd/cpu/pysiglib(row)"), runs, || {
            std::hint::black_box(batch_kernel(
                &xs,
                &ys,
                b,
                l,
                l,
                d,
                &KernelOptions::default(),
            ));
        });

        // ---------------- forward, GPU-scheme ----------------
        // sigkernel's GPU kernel refuses diagonals beyond 1024 threads.
        let diag_len = l; // rows == cols == l-1, diagonal l
        if diag_len >= 1024 {
            suite.record(&format!("{tag}/fwd/gpu/sigkernel-like(thread-limited)"), f64::NAN);
        } else {
            suite.time(&format!("{tag}/fwd/gpu/sigkernel-like(thread-limited)"), runs, || {
                parallel_for(b, |i| {
                    let (m, n, delta) = delta_matrix(
                        &xs[i * l * d..(i + 1) * l * d],
                        &ys[i * l * d..(i + 1) * l * d],
                        l,
                        l,
                        d,
                        Transform::None,
                    );
                    std::hint::black_box(gpu_style_kernel(&delta, m, n, 0, 0).unwrap());
                });
            });
        }
        suite.time(&format!("{tag}/fwd/gpu/pysiglib(blocked)"), runs, || {
            std::hint::black_box(batch_kernel(
                &xs,
                &ys,
                b,
                l,
                l,
                d,
                &KernelOptions::default().solver(SolverKind::Blocked),
            ));
        });

        // ---------------- backward ----------------
        let gk = vec![1.0; b];
        suite.time(&format!("{tag}/bwd/cpu/sigkernel-like(pde-approx)"), runs, || {
            parallel_for(b, |i| {
                std::hint::black_box(sig_kernel_vjp_pde_approx(
                    &xs[i * l * d..(i + 1) * l * d],
                    &ys[i * l * d..(i + 1) * l * d],
                    l,
                    l,
                    d,
                    &KernelOptions::default(),
                    1.0,
                ));
            });
        });
        suite.time(&format!("{tag}/bwd/cpu/pysiglib(exact)"), runs, || {
            std::hint::black_box(batch_kernel_vjp(
                &xs,
                &ys,
                &gk,
                b,
                l,
                l,
                d,
                &KernelOptions::default(),
            ));
        });
    }

    println!("\nspeedup summary (sigkernel-like / pysiglib):");
    for (b, l, d) in configs {
        let tag = format!("B{b}_L{l}_d{d}");
        let base_f = suite.get(&format!("{tag}/fwd/cpu/sigkernel-like(fullgrid)"));
        let py_f = suite.get(&format!("{tag}/fwd/cpu/pysiglib(row)"));
        let base_b = suite.get(&format!("{tag}/bwd/cpu/sigkernel-like(pde-approx)"));
        let py_b = suite.get(&format!("{tag}/bwd/cpu/pysiglib(exact)"));
        if let (Some(bf), Some(pf), Some(bb), Some(pb)) = (base_f, py_f, base_b, py_b) {
            println!("  {tag}: fwd {:.2}x, bwd {:.2}x", bf / pf, bb / pb);
        }
    }
}
